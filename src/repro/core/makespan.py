"""Throughput-policy placement: minimize max_g max(T_g, M_g).

The paper's throughput objective (§III-B): under steady-state pipelined
execution each device alternates compute and communication, so its stage
time is W_g = max(T_g, M_g) with
    T_g = sum of kernel times assigned to g,
    M_g = sum of transfer costs over incoming cut edges of g,
and system throughput is 1 / max_g W_g.

The MILP is NP-hard (min-max makespan with communication); the paper uses
Gurobi offline.  We implement:
  * three construction seeds (best-device, topological LPT, roofline split),
  * first-improvement local search over single-node moves,
  * simulated annealing refinement (seeded, deterministic),
  * layer folding (paper §V-D): repeated layers are planned once and the
    placement broadcast to structurally identical siblings.
An exact branch-and-bound oracle (bnb.py) verifies optimality on small
graphs in the test suite.
"""
from __future__ import annotations

import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.graph import KernelGraph


class MakespanProblem:
    """Pre-indexed incremental evaluator of W(x)."""

    def __init__(self, graph: KernelGraph, devices,
                 bw_override: Optional[float] = None):
        self.graph = graph
        self.devices = devices
        self.nG = len(devices)
        self.n = len(graph)
        self.t = [[dev.kernel_time(nd) for dev in devices]
                  for nd in graph.nodes]
        # edge transfer cost per (device_u, device_g) pair
        self.edges = list(graph.edges.items())   # ((i, j), bytes)
        self.c = {}
        for (i, j), nb in self.edges:
            rep = max(graph.nodes[i].repeat, graph.nodes[j].repeat)
            for u in range(self.nG):
                for g in range(self.nG):
                    if u != g:
                        self.c[(i, j, u, g)] = devices[u].transfer_time(
                            nb, devices[g], bw_override, repeat=rep)
        self.out_edges: List[List[Tuple[int, float]]] = [[] for _ in range(self.n)]
        self.in_edges: List[List[Tuple[int, float]]] = [[] for _ in range(self.n)]
        for (i, j), nb in self.edges:
            self.out_edges[i].append((j, nb))
            self.in_edges[j].append((i, nb))
        self.pins = {nd.idx: nd.pinned for nd in graph.nodes
                     if nd.pinned is not None}

    # -- objective ----------------------------------------------------- #
    def loads(self, x: Sequence[int]) -> Tuple[List[float], List[float]]:
        T = [0.0] * self.nG
        M = [0.0] * self.nG
        for k in range(self.n):
            T[x[k]] += self.t[k][x[k]]
        for (i, j), nb in self.edges:
            u, g = x[i], x[j]
            if u != g:
                M[g] += self.c[(i, j, u, g)]
        return T, M

    def objective(self, x: Sequence[int]) -> float:
        T, M = self.loads(x)
        return max(max(t, m) for t, m in zip(T, M))

    def valid(self, x: Sequence[int]) -> bool:
        return all(x[k] == d for k, d in self.pins.items())

    # -- seeds ---------------------------------------------------------- #
    def seed_best_device(self) -> List[int]:
        x = [min(range(self.nG), key=lambda g: self.t[k][g])
             for k in range(self.n)]
        self._apply_pins(x)
        return x

    def seed_lpt(self) -> List[int]:
        """Topological greedy: place each node on the device minimizing the
        incremental bottleneck (classic LPT adapted with comm costs)."""
        x = [-1] * self.n
        T = [0.0] * self.nG
        M = [0.0] * self.nG
        for k in range(self.n):
            pin = self.pins.get(k)
            cands = [pin] if pin is not None else range(self.nG)
            best_g, best_w = None, math.inf
            for g in cands:
                dT = self.t[k][g]
                dM = 0.0
                for (i, _nb) in self.in_edges[k]:
                    if x[i] >= 0 and x[i] != g:
                        dM += self.c[(i, k, x[i], g)]
                w = max(max(T[g] + dT, M[g] + dM),
                        max(max(T), max(M)) if self.n else 0.0)
                if w < best_w:
                    best_w, best_g = w, g
            x[k] = best_g
            T[best_g] += self.t[k][best_g]
            for (i, _nb) in self.in_edges[k]:
                if x[i] != best_g:
                    M[best_g] += self.c[(i, k, x[i], best_g)]
        return x

    def seed_roofline_split(self) -> List[int]:
        """Compute-bound kernels -> highest peak-FLOPs device;
        memory-bound -> highest-bandwidth device (paper Fig. 3 intuition)."""
        g_flops = max(range(self.nG),
                      key=lambda g: self.devices[g].peak_flops)
        g_bw = max(range(self.nG), key=lambda g: self.devices[g].hbm_bw)
        x = []
        for nd in self.graph.nodes:
            ridge = (self.devices[g_flops].peak_flops /
                     self.devices[g_flops].hbm_bw)
            x.append(g_flops if nd.intensity >= ridge else g_bw)
        self._apply_pins(x)
        return x

    def _apply_pins(self, x: List[int]) -> None:
        for k, d in self.pins.items():
            x[k] = d

    # -- local search ---------------------------------------------------#
    def local_search(self, x: List[int], max_passes: int = 12) -> List[int]:
        x = list(x)
        cur = self.objective(x)
        for _ in range(max_passes):
            improved = False
            for k in range(self.n):
                if k in self.pins:
                    continue
                old = x[k]
                for g in range(self.nG):
                    if g == old:
                        continue
                    x[k] = g
                    w = self.objective(x)
                    if w < cur - 1e-15:
                        cur = w
                        old = g
                        improved = True
                x[k] = old
            if not improved:
                break
        return x

    def anneal(self, x: List[int], iters: int = 4000,
               seed: int = 0) -> List[int]:
        rng = random.Random(seed)
        x = list(x)
        cur = self.objective(x)
        best, best_w = list(x), cur
        free = [k for k in range(self.n) if k not in self.pins]
        if not free or self.nG < 2:
            return best
        t0 = cur * 0.2 + 1e-12
        for it in range(iters):
            temp = t0 * (1.0 - it / iters) + 1e-15
            k = rng.choice(free)
            g = rng.randrange(self.nG)
            if g == x[k]:
                continue
            old = x[k]
            x[k] = g
            w = self.objective(x)
            if w < cur or rng.random() < math.exp((cur - w) / temp):
                cur = w
                if w < best_w:
                    best_w, best = w, list(x)
            else:
                x[k] = old
        return best


def solve_throughput(graph: KernelGraph, devices,
                     bw_override: Optional[float] = None,
                     anneal_iters: int = 4000,
                     seed: int = 0) -> Tuple[List[int], float]:
    """Best placement over all seeds + refinement. Deterministic."""
    prob = MakespanProblem(graph, devices, bw_override)
    cands = [prob.seed_best_device(), prob.seed_lpt(),
             prob.seed_roofline_split()]
    best, best_w = None, math.inf
    for x in cands:
        x = prob.local_search(x)
        w = prob.objective(x)
        if w < best_w:
            best, best_w = x, w
    x = prob.anneal(best, iters=anneal_iters, seed=seed)
    x = prob.local_search(x)
    w = prob.objective(x)
    if w < best_w:
        best, best_w = x, w
    assert prob.valid(best)
    return best, best_w


# --------------------------------------------------------------------- #
# Layer folding (paper §V-D): plan one representative of each group of
# structurally identical layers and broadcast the placement.
# --------------------------------------------------------------------- #
def fold_and_solve(graph: KernelGraph, devices, solver,
                   **solver_kwargs) -> Tuple[List[int], float]:
    """``solver(graph, devices, **kwargs) -> (labels, obj)`` applied to a
    folded problem.  Nodes of non-representative layers inherit the
    placement of the structurally matching node in the representative.
    Falls back to the full solve when folding finds no repetition.
    """
    groups = graph.layer_signature_groups()
    rep_layers = {min(layers): layers for layers in groups.values()
                  if len(layers) > 1}
    if not rep_layers:
        return solver(graph, devices, **solver_kwargs)

    folded_members = {l for layers in rep_layers.values() for l in layers}
    keep = [n.idx for n in graph.nodes
            if n.layer not in folded_members or n.layer in rep_layers]
    keep_set = set(keep)
    remap = {old: new for new, old in enumerate(keep)}

    # Map any node in a folded (non-representative) layer to the node at
    # the same intra-layer position in its representative.
    by_layer: Dict[int, List[int]] = {}
    for n in graph.nodes:
        by_layer.setdefault(n.layer, []).append(n.idx)
    layer_rep = {}
    for rep, layers in rep_layers.items():
        for l in layers:
            layer_rep[l] = rep
    to_rep: Dict[int, int] = {}
    for n in graph.nodes:
        rep = layer_rep.get(n.layer)
        if rep is None or n.layer == rep:
            to_rep[n.idx] = n.idx
        else:
            pos = by_layer[n.layer].index(n.idx)
            to_rep[n.idx] = by_layer[rep][pos]

    import dataclasses as _dc
    rep_count = {rep: len(layers) for rep, layers in rep_layers.items()}
    sub_nodes = []
    for old in keep:
        nd = graph.nodes[old]
        mult = rep_count.get(nd.layer, 1)
        sub_nodes.append(_dc.replace(
            nd, idx=remap[old],
            flops=nd.flops * mult,
            bytes_accessed=nd.bytes_accessed * mult,
            eqn_ids=nd.eqn_ids))
    # Edges: remap endpoints onto representatives so every cut cost in the
    # full graph is represented (scaled by its multiplicity) in the folded
    # one.  Without this, M_g is undercounted by the fold factor and the
    # solver over-cuts.
    sub_edges: Dict[Tuple[int, int], float] = {}
    for (i, j), b in graph.edges.items():
        ri, rj = to_rep[i], to_rep[j]
        if ri == rj:
            continue                    # inter-layer copy of a fold: the
                                        # same-position self edge is moot
        a, c = remap[ri], remap[rj]
        if a == c:
            continue
        key = (min(a, c), max(a, c))
        sub_edges[key] = sub_edges.get(key, 0.0) + b
    sub = KernelGraph(sub_nodes, sub_edges, name=graph.name + "+folded")
    labels_sub, _ = solver(sub, devices, **solver_kwargs)

    # Broadcast placement: match nodes by (layer-relative position).
    by_layer: Dict[int, List[int]] = {}
    for n in graph.nodes:
        by_layer.setdefault(n.layer, []).append(n.idx)
    labels = [0] * len(graph)
    for old in keep:
        labels[old] = labels_sub[remap[old]]
    for rep, layers in rep_layers.items():
        rep_nodes = by_layer[rep]
        for l in layers:
            if l == rep:
                continue
            for pos, old in enumerate(by_layer[l]):
                labels[old] = labels[rep_nodes[pos]]
    # Honor pins on non-representative layers.
    for n in graph.nodes:
        if n.pinned is not None:
            labels[n.idx] = n.pinned
    prob = MakespanProblem(graph, devices,
                           solver_kwargs.get("bw_override"))
    return labels, prob.objective(labels)
