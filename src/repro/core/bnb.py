"""Exact branch-and-bound placement oracle (small graphs only).

Used by the test suite to certify that the min-cut solver is globally
optimal (it must match this oracle exactly on the latency objective) and
that the makespan heuristics land within a small factor of optimal.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.graph import KernelGraph
from repro.core.makespan import MakespanProblem


def solve_exact(graph: KernelGraph, devices, objective: str = "throughput",
                bw_override: Optional[float] = None,
                node_limit: int = 18) -> Tuple[List[int], float]:
    """Exhaustive DFS with admissible pruning. O(|G|^n) worst case."""
    n = len(graph)
    if n > node_limit:
        raise ValueError(f"graph too large for exact solve ({n} nodes)")
    prob = MakespanProblem(graph, devices, bw_override)
    nG = prob.nG
    best_x: List[int] = []
    best_w = math.inf
    x = [0] * n
    t_min = [min(prob.t[k]) for k in range(n)]

    def lat_partial(k: int) -> float:
        """Latency objective of prefix [0, k) + admissible remainder."""
        e = sum(prob.t[i][x[i]] for i in range(k))
        for (i, j), _nb in prob.edges:
            if i < k and j < k and x[i] != x[j]:
                e += prob.c[(i, j, x[i], x[j])]
        return e + sum(t_min[k:])

    def thr_partial(k: int) -> float:
        T = [0.0] * nG
        M = [0.0] * nG
        for i in range(k):
            T[x[i]] += prob.t[i][x[i]]
        for (i, j), _nb in prob.edges:
            if i < k and j < k and x[i] != x[j]:
                M[x[j]] += prob.c[(i, j, x[i], x[j])]
        lb1 = max(max(t, m) for t, m in zip(T, M))
        lb2 = (sum(T) + sum(t_min[k:])) / nG      # average-load bound
        return max(lb1, lb2)

    bound = lat_partial if objective == "latency" else thr_partial

    def full(xx: List[int]) -> float:
        if objective == "latency":
            e = sum(prob.t[i][xx[i]] for i in range(n))
            for (i, j), _nb in prob.edges:
                if xx[i] != xx[j]:
                    e += prob.c[(i, j, xx[i], xx[j])]
            return e
        return prob.objective(xx)

    def dfs(k: int) -> None:
        nonlocal best_w, best_x
        if k == n:
            w = full(x)
            if w < best_w:
                best_w, best_x = w, list(x)
            return
        pin = prob.pins.get(k)
        for g in ([pin] if pin is not None else range(nG)):
            x[k] = g
            if bound(k + 1) < best_w - 1e-15:
                dfs(k + 1)
        x[k] = 0

    dfs(0)
    return best_x, best_w
