"""Online monitor: queueing-aware policy switching (paper §III-D).

Tracks per-request end-to-end latency and pure execution latency
(compute + communication, excluding queueing).  At each window boundary
(every ``W`` seconds of workload time) the ratio  L̄_req / L̄_exec  measures
queueing pressure:

  ratio <= beta  ->  latency-oriented policy (light load)
  ratio  > beta  ->  throughput-oriented policy (queueing dominates)

Each switch stalls all workers for ``switch_stall`` seconds at an
iteration boundary (the paper measures ~30 ms).  The monitor also
aggregates *kernel-group* latency — the time between consecutive
communication events — rather than per-kernel timing, matching the
paper's low-overhead monitoring granularity.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    # Frozen: MonitorConfig() is used as a default argument (one shared
    # instance per process), so it must be immutable.
    window: float = 0.300        # W  (paper default 300 ms)
    beta: float = 1.5            # queueing threshold (paper default 1.5)
    switch_stall: float = 0.030  # worker sync stall per switch (paper ~30ms)
    min_samples: int = 1
    # Hysteresis band around beta: switch to throughput only above
    # beta*(1+h), back to latency only below beta*(1-h).  A ratio
    # hovering at beta would otherwise flap every window, paying the
    # switch stall each time for no routing benefit.
    hysteresis: float = 0.05


class OnlineMonitor:
    """Feed samples; read ``policy`` ("latency" | "throughput")."""

    def __init__(self, config: MonitorConfig = MonitorConfig(),
                 initial_policy: str = "latency"):
        self.cfg = config
        self.policy = initial_policy
        self.switches = 0
        self.stall_time = 0.0
        # O(1) incremental window accumulators (running sums in sample
        # order are bit-identical to summing the historical per-window
        # lists left-to-right, and drop the per-window list rebuilds)
        self._req_n = 0
        self._req_sum = 0.0
        self._exec_sum = 0.0
        self._grp_n = 0
        self._grp_sum = 0.0
        self._window_end: Optional[float] = None
        # (t, policy, ratio, mean_group_latency) per closed window with
        # enough samples; mean_group_latency aggregates the
        # record_kernel_group feed (0.0 when no group samples landed)
        self.history: List[Tuple[float, str, float, float]] = []

    # ------------------------------------------------------------------ #
    def record_request(self, now: float, request_latency: float,
                       exec_latency: float) -> None:
        if self._window_end is None:
            self._window_end = now + self.cfg.window
        self._req_n += 1
        self._req_sum += request_latency
        self._exec_sum += exec_latency
        if now >= self._window_end:    # _maybe_switch guard, hoisted
            self._maybe_switch(now)

    def record_kernel_group(self, seconds: float) -> None:
        """Latency of a kernel group = span between consecutive
        communication ops (cheap monitoring unit, paper §III-D)."""
        self._grp_n += 1
        self._grp_sum += seconds

    def tick(self, now: float) -> None:
        """Advance workload time without a sample (idle windows)."""
        if self._window_end is None:
            # A group that is idle from the start only ever sees ticks;
            # if they cannot open the first window, the monitor stays
            # inert forever and never re-evaluates once load arrives.
            self._window_end = now + self.cfg.window
            return
        self._maybe_switch(now)

    # ------------------------------------------------------------------ #
    def _maybe_switch(self, now: float) -> None:
        if self._window_end is None or now < self._window_end:
            return
        if self._req_n >= self.cfg.min_samples:
            n = self._req_n
            ratio = (self._req_sum / n) / max(self._exec_sum / n, 1e-12)
            up = self.cfg.beta * (1.0 + self.cfg.hysteresis)
            down = self.cfg.beta * (1.0 - self.cfg.hysteresis)
            if ratio > up:
                target = "throughput"
            elif ratio < down:
                target = "latency"
            else:                      # inside the band: hold (no flap)
                target = self.policy
            if target != self.policy:
                self.policy = target
                self.switches += 1
                self.stall_time += self.cfg.switch_stall
            grp = (self._grp_sum / self._grp_n if self._grp_n else 0.0)
            self.history.append((now, self.policy, ratio, grp))
        self._req_n = 0
        self._req_sum = 0.0
        self._exec_sum = 0.0
        self._grp_n = 0
        self._grp_sum = 0.0
        # advance in whole windows so long gaps don't cause switch storms
        k = max(1, int((now - self._window_end) / self.cfg.window) + 1)
        self._window_end += k * self.cfg.window
