# Tessera core: kernel-granularity disaggregation for heterogeneous
# accelerators, adapted from the paper's CUDA implementation to JAX/TPU.
#
#   analyzer   — jaxpr -> KernelGraph (exact RAW deps; replaces PTX pass)
#   costmodel  — device catalog + roofline kernel latency
#   planner    — latency (exact min-cut) / throughput (makespan) policies
#   executor   — per-device staged jitted execution with explicit transfers
#   pipeline   — multi-request pipelining with priority aging + stragglers
#   monitor    — queueing-aware online policy switching
#   simulator  — discrete-event model for the paper's perf experiments

from repro.core.analyzer import TracedGraph, analyze, pin_nodes
from repro.core.costmodel import (CATALOG, DeviceSpec, PAPER_PAIRS,
                                  TPU_PAIRS, cost_matrix)
from repro.core.executor import StagedExecutable, build_executable
from repro.core.graph import KernelGraph, KernelNode
from repro.core.monitor import MonitorConfig, OnlineMonitor
from repro.core.pipeline import PipelinedRunner
from repro.core.planner import Plan, Stage, plan, replan_on_failure
from repro.core.simulator import SimResult, simulate_offline, simulate_online

__all__ = [
    "TracedGraph", "analyze", "pin_nodes", "CATALOG", "DeviceSpec",
    "PAPER_PAIRS", "TPU_PAIRS", "cost_matrix", "StagedExecutable",
    "build_executable", "KernelGraph", "KernelNode", "MonitorConfig",
    "OnlineMonitor", "PipelinedRunner", "Plan", "Stage", "plan",
    "replan_on_failure", "SimResult", "simulate_offline", "simulate_online",
]
