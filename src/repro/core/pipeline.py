"""Pipelined multi-request execution (paper §III-C).

The paper keeps heterogeneous GPUs busy by running several requests
concurrently on separate CUDA streams with *priority-aware* scheduling
(earlier requests get more SM time, staggering their communication
phases).  TPU/XLA exposes neither user streams nor priorities, so the
TPU-idiomatic equivalent is implemented at the host level:

  * JAX async dispatch makes every dispatch-unit call non-blocking;
    issuing units of *different* requests back-to-back overlaps one
    request's transfers with another's compute — the same effect as
    multi-stream pipelining.
  * The runner drives the executor's **indexed dispatch program**
    (executor.py): a flat slot environment per request and fused
    dispatch units, so the scheduling loop does no Var hashing and
    dispatches once per physical-device alternation, not once per plan
    stage.
  * A host-side run queue dispatches the next unit of the *oldest*
    incomplete request first (strict priority by arrival, the paper's
    stream-priority policy), or round-robin ("naive") for ablation.
  * Straggler mitigation: an optional wall-clock deadline per unit; on
    expiry the unit is re-executed on a fallback device (units are pure
    functions, so duplicate execution is always safe — the first result
    to arrive wins).
  * Per-unit dispatch timings and transfer counts are recorded so
    benchmarks can attribute host overhead to stages.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor, TimeoutError as FTimeout
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.executor import StagedExecutable


@dataclasses.dataclass
class RequestState:
    rid: int
    args: tuple
    kwargs: dict
    slots: Optional[list] = None        # indexed env (executor fast path)
    next_unit: int = 0
    submitted: float = 0.0
    finished: float = 0.0
    output: Any = None

    @property
    def done(self) -> bool:
        return self.output is not None


@dataclasses.dataclass
class PipelineStats:
    completed: int = 0
    wall_seconds: float = 0.0
    stage_dispatches: int = 0           # fused dispatch units issued
    transfers: int = 0                  # eager cross-device sends issued
    straggler_reexecs: int = 0
    per_request_latency: List[float] = dataclasses.field(default_factory=list)
    # host-side dispatch time accumulated per unit index (seconds);
    # async dispatch means this is issue overhead, not device time.
    unit_dispatch_seconds: Dict[int, float] = dataclasses.field(
        default_factory=dict)

    @property
    def throughput(self) -> float:
        return self.completed / max(self.wall_seconds, 1e-9)

    def dispatch_overhead(self) -> float:
        """Total host seconds spent issuing work."""
        return sum(self.unit_dispatch_seconds.values())


class PipelinedRunner:
    """Drives N in-flight requests through a StagedExecutable."""

    def __init__(self, executable: StagedExecutable,
                 max_inflight: int = 4,
                 scheduling: str = "priority",     # "priority" | "naive"
                 straggler_deadline: Optional[float] = None,
                 fallback_device: Any = None):
        assert scheduling in ("priority", "naive")
        self.exe = executable
        self.max_inflight = max_inflight
        self.scheduling = scheduling
        self.straggler_deadline = straggler_deadline
        self.fallback_device = fallback_device
        self._pool = (ThreadPoolExecutor(max_workers=2)
                      if straggler_deadline else None)

    # ------------------------------------------------------------------ #
    def run(self, requests: Sequence[Tuple[tuple, dict]]) -> Tuple[
            List[Any], PipelineStats]:
        """Process all requests; returns (outputs in submit order, stats)."""
        stats = PipelineStats()
        t0 = time.perf_counter()
        states = [RequestState(rid=i, args=a, kwargs=k, submitted=t0)
                  for i, (a, k) in enumerate(requests)]
        pending = list(range(len(states)))      # not yet admitted
        inflight: List[int] = []
        n_units = self.exe.num_units
        rr = 0                                   # round-robin cursor

        while pending or inflight:
            while pending and len(inflight) < self.max_inflight:
                rid = pending.pop(0)
                states[rid].slots = self.exe.init_slots(
                    *states[rid].args, **states[rid].kwargs)
                inflight.append(rid)

            if self.scheduling == "priority":
                rid = min(inflight)              # oldest incomplete first
            else:
                rid = inflight[rr % len(inflight)]
                rr += 1
            st = states[rid]
            self._dispatch_unit(st, stats)
            stats.stage_dispatches += 1

            if st.next_unit >= n_units:
                st.output = self.exe.collect_slots(st.slots)
                # block to get an honest completion time
                jax.block_until_ready(st.output)
                st.finished = time.perf_counter()
                stats.per_request_latency.append(st.finished - st.submitted)
                stats.completed += 1
                inflight.remove(rid)

        stats.wall_seconds = time.perf_counter() - t0
        return [s.output for s in states], stats

    # ------------------------------------------------------------------ #
    def _dispatch_unit(self, st: RequestState, stats: PipelineStats):
        idx = st.next_unit
        t0 = time.perf_counter()
        if self.straggler_deadline is None:
            stats.transfers += self.exe.run_unit(st.slots, idx)
        else:
            fut = self._pool.submit(self._run_blocking, st.slots, idx)
            try:
                stats.transfers += fut.result(
                    timeout=self.straggler_deadline)
            except FTimeout:
                # Straggler: re-execute on the fallback device.  Pure
                # unit functions make duplicate execution safe; the
                # rerun's results overwrite the slot bindings.
                stats.straggler_reexecs += 1
                stats.transfers += self.exe.run_unit(
                    st.slots, idx, device_override=self.fallback_device)
                jax.block_until_ready(
                    self.exe.unit_outputs(st.slots, idx))
        dt = time.perf_counter() - t0
        stats.unit_dispatch_seconds[idx] = \
            stats.unit_dispatch_seconds.get(idx, 0.0) + dt
        st.next_unit += 1

    def _run_blocking(self, slots, idx) -> int:
        n = self.exe.run_unit(slots, idx)
        jax.block_until_ready(self.exe.unit_outputs(slots, idx))
        return n
