"""Pipelined multi-request execution (paper §III-C).

The paper keeps heterogeneous GPUs busy by running several requests
concurrently on separate CUDA streams with *priority-aware* scheduling
(earlier requests get more SM time, staggering their communication
phases).  TPU/XLA exposes neither user streams nor priorities, so the
TPU-idiomatic equivalent is implemented at the host level:

  * JAX async dispatch makes every stage call non-blocking; issuing stages
    of *different* requests back-to-back overlaps one request's transfers
    with another's compute — the same effect as multi-stream pipelining.
  * A host-side run queue dispatches the next stage of the *oldest*
    incomplete request first (strict priority by arrival, the paper's
    stream-priority policy), or round-robin ("naive") for ablation.
  * Straggler mitigation: an optional wall-clock deadline per stage; on
    expiry the stage is re-executed on a fallback device (stages are pure
    functions, so duplicate execution is always safe — the first result to
    arrive wins).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from concurrent.futures import ThreadPoolExecutor, TimeoutError as FTimeout
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.executor import StagedExecutable


@dataclasses.dataclass
class RequestState:
    rid: int
    args: tuple
    kwargs: dict
    env: Optional[dict] = None
    next_stage: int = 0
    submitted: float = 0.0
    finished: float = 0.0
    output: Any = None

    @property
    def done(self) -> bool:
        return self.output is not None


@dataclasses.dataclass
class PipelineStats:
    completed: int = 0
    wall_seconds: float = 0.0
    stage_dispatches: int = 0
    straggler_reexecs: int = 0
    per_request_latency: List[float] = dataclasses.field(default_factory=list)

    @property
    def throughput(self) -> float:
        return self.completed / max(self.wall_seconds, 1e-9)


class PipelinedRunner:
    """Drives N in-flight requests through a StagedExecutable."""

    def __init__(self, executable: StagedExecutable,
                 max_inflight: int = 4,
                 scheduling: str = "priority",     # "priority" | "naive"
                 straggler_deadline: Optional[float] = None,
                 fallback_device: Any = None):
        assert scheduling in ("priority", "naive")
        self.exe = executable
        self.max_inflight = max_inflight
        self.scheduling = scheduling
        self.straggler_deadline = straggler_deadline
        self.fallback_device = fallback_device
        self._pool = (ThreadPoolExecutor(max_workers=2)
                      if straggler_deadline else None)

    # ------------------------------------------------------------------ #
    def run(self, requests: Sequence[Tuple[tuple, dict]]) -> Tuple[
            List[Any], PipelineStats]:
        """Process all requests; returns (outputs in submit order, stats)."""
        stats = PipelineStats()
        t0 = time.perf_counter()
        states = [RequestState(rid=i, args=a, kwargs=k, submitted=t0)
                  for i, (a, k) in enumerate(requests)]
        pending = list(range(len(states)))      # not yet admitted
        inflight: List[int] = []
        n_stages = len(self.exe.stages)
        rr = 0                                   # round-robin cursor

        while pending or inflight:
            while pending and len(inflight) < self.max_inflight:
                rid = pending.pop(0)
                states[rid].env = self.exe.init_env(
                    *states[rid].args, **states[rid].kwargs)
                inflight.append(rid)

            if self.scheduling == "priority":
                rid = min(inflight)              # oldest incomplete first
            else:
                rid = inflight[rr % len(inflight)]
                rr += 1
            st = states[rid]
            self._dispatch_stage(st, stats)
            stats.stage_dispatches += 1

            if st.next_stage >= n_stages:
                st.output = self.exe.collect_outputs(st.env)
                # block to get an honest completion time
                jax.block_until_ready(st.output)
                st.finished = time.perf_counter()
                stats.per_request_latency.append(st.finished - st.submitted)
                stats.completed += 1
                inflight.remove(rid)

        stats.wall_seconds = time.perf_counter() - t0
        return [s.output for s in states], stats

    # ------------------------------------------------------------------ #
    def _dispatch_stage(self, st: RequestState, stats: PipelineStats):
        idx = st.next_stage
        if self.straggler_deadline is None:
            self.exe.run_stage(st.env, idx)
        else:
            fut = self._pool.submit(self._run_blocking, st.env, idx)
            try:
                fut.result(timeout=self.straggler_deadline)
            except FTimeout:
                # Straggler: re-execute on the fallback device.  Pure
                # stage functions make duplicate execution safe; the
                # rerun's results overwrite the env bindings.
                stats.straggler_reexecs += 1
                self.exe.run_stage(st.env, idx,
                                   device_override=self.fallback_device)
                jax.block_until_ready(
                    [st.env[v] for v in self.exe.stages[idx].outvars])
        st.next_stage += 1

    def _run_blocking(self, env, idx):
        self.exe.run_stage(env, idx)
        jax.block_until_ready([env[v] for v in self.exe.stages[idx].outvars])
