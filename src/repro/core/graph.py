"""Kernel graph: the data-dependency graph (DDG) Tessera plans over.

The paper extracts this graph from instrumented PTX; here it is derived
from a jaxpr (see ``analyzer.py``), so every node carries exact FLOP and
byte counts and every edge carries the exact transfer size of the buffer
that crosses it (Read-After-Write dependency).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class KernelNode:
    """One schedulable kernel (a jaxpr equation or a fused group of them)."""

    idx: int                      # topological index (jaxprs are topo-sorted)
    name: str                     # primitive name, e.g. "dot_general"
    flops: float                  # floating point operations
    bytes_accessed: float         # HBM traffic estimate (reads + writes)
    out_bytes: float              # bytes of produced buffers (transfer size)
    # Tags used by the coarse-grained baselines and by layer folding:
    phase: str = ""               # "prefill" | "decode" | "" (PD baseline)
    block: str = ""               # "attention" | "ffn" | "moe" | "ssm" | ...
    layer: int = -1               # layer index, -1 = not part of a layer
    pinned: Optional[int] = None  # device id this node MUST run on (KV etc.)
    fused: int = 1                # how many raw equations were fused in
    repeat: int = 1               # launch multiplicity (decode iterations)
    eqn_ids: Tuple[int, ...] = ()  # raw equation indices composing this node

    @property
    def intensity(self) -> float:
        """Operational intensity (FLOP/byte) — the roofline x-axis."""
        return self.flops / max(self.bytes_accessed, 1.0)

    def signature(self) -> Tuple:
        """Structural signature used for layer folding (paper §V-D)."""
        return (self.name, round(self.flops), round(self.bytes_accessed),
                round(self.out_bytes), self.block)


@dataclasses.dataclass
class KernelGraph:
    """DDG: nodes in topological order + RAW edges annotated with bytes.

    ``edges[(i, j)] = nbytes`` means node j reads nbytes produced by node i.
    Edges are deduplicated (multiple buffers between the same pair sum up).
    """

    nodes: List[KernelNode]
    edges: Dict[Tuple[int, int], float]
    name: str = "ddg"

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def successors(self, i: int) -> List[int]:
        return [j for (a, j) in self.edges if a == i]

    def predecessors(self, j: int) -> List[int]:
        return [i for (i, b) in self.edges if b == j]

    def adjacency(self) -> Tuple[Dict[int, List[Tuple[int, float]]],
                                 Dict[int, List[Tuple[int, float]]]]:
        """(out_adj, in_adj) as {node: [(other, bytes), ...]}."""
        out: Dict[int, List[Tuple[int, float]]] = {n.idx: [] for n in self.nodes}
        inn: Dict[int, List[Tuple[int, float]]] = {n.idx: [] for n in self.nodes}
        for (i, j), b in self.edges.items():
            out[i].append((j, b))
            inn[j].append((i, b))
        return out, inn

    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes)

    def total_bytes(self) -> float:
        return sum(n.bytes_accessed for n in self.nodes)

    # ------------------------------------------------------------------ #
    def validate(self) -> None:
        """Invariants: topo order respected, edge endpoints exist."""
        ids = {n.idx for n in self.nodes}
        assert ids == set(range(len(self.nodes))), "node idx must be dense"
        for (i, j), b in self.edges.items():
            assert i in ids and j in ids, f"dangling edge ({i},{j})"
            assert i < j, f"edge ({i},{j}) violates topological order"
            assert b >= 0

    # ------------------------------------------------------------------ #
    def fuse_elementwise(self) -> "KernelGraph":
        """Merge cheap single-consumer elementwise producers into consumers.

        XLA fuses elementwise chains into their consumers; planning at raw
        eqn granularity would overstate both kernel counts and transfer
        opportunities (DESIGN.md §2).  A node is absorbed into its consumer
        when it (a) is elementwise-ish (zero-FLOP reshapes/converts or
        O(n) math), (b) has exactly one consumer, and (c) shares no other
        placement constraint (not pinned differently).
        """
        out_adj, _ = self.adjacency()
        consumers = {n.idx: [j for j, _ in out_adj[n.idx]] for n in self.nodes}
        # Union-find: each raw node -> representative (its final consumer).
        parent = list(range(len(self.nodes)))

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for n in self.nodes:
            cs = consumers[n.idx]
            if len(cs) != 1:
                continue
            c = cs[0]
            if not _fusible(n):
                continue
            cn = self.nodes[c]
            if n.pinned is not None and cn.pinned is not None \
                    and n.pinned != cn.pinned:
                continue
            # Never fuse across layer/block boundaries: folding (paper
            # §V-D) relies on repeated layers staying structurally
            # identical, and tags staying meaningful.
            if n.layer != cn.layer or n.block != cn.block \
                    or n.phase != cn.phase:
                continue
            parent[find(n.idx)] = find(c)

        groups: Dict[int, List[int]] = {}
        for n in self.nodes:
            groups.setdefault(find(n.idx), []).append(n.idx)

        # New node per group, ordered by representative's topo position.
        reps = sorted(groups)
        remap = {}
        new_nodes: List[KernelNode] = []
        for new_idx, rep in enumerate(reps):
            members = groups[rep]
            rep_node = self.nodes[rep]
            pin = None
            eqn_ids: List[int] = []
            for m in members:
                remap[m] = new_idx
                mn = self.nodes[m]
                if mn.pinned is not None:
                    pin = mn.pinned
                eqn_ids.extend(mn.eqn_ids or (m,))
            new_nodes.append(KernelNode(
                idx=new_idx,
                name=rep_node.name,
                flops=sum(self.nodes[m].flops for m in members),
                bytes_accessed=sum(self.nodes[m].bytes_accessed
                                   for m in members),
                out_bytes=rep_node.out_bytes,
                phase=rep_node.phase,
                block=rep_node.block,
                layer=rep_node.layer,
                pinned=pin,
                fused=sum(self.nodes[m].fused for m in members),
                eqn_ids=tuple(sorted(eqn_ids)),
            ))
        new_edges: Dict[Tuple[int, int], float] = {}
        for (i, j), b in self.edges.items():
            a, c = remap[i], remap[j]
            if a == c:
                continue
            # Producer-into-consumer fusion can only move endpoints forward,
            # so topological order (a < c) is preserved.
            key = (a, c)
            new_edges[key] = new_edges.get(key, 0.0) + b
        g = KernelGraph(new_nodes, new_edges, name=self.name + "+fused")
        g.validate()
        return g

    # ------------------------------------------------------------------ #
    def layer_signature_groups(self) -> Dict[Tuple, List[int]]:
        """Group layer ids by identical structural signature (folding)."""
        sigs: Dict[Tuple, List[int]] = {}
        by_layer: Dict[int, List[KernelNode]] = {}
        for n in self.nodes:
            if n.layer >= 0:
                by_layer.setdefault(n.layer, []).append(n)
        for layer, nodes in by_layer.items():
            sig = tuple(sorted(n.signature() for n in nodes))
            h = hashlib.sha1(repr(sig).encode()).hexdigest()
            sigs.setdefault(h, []).append(layer)
        return sigs

    def stats(self) -> Dict[str, Any]:
        return dict(
            nodes=len(self.nodes),
            edges=len(self.edges),
            gflops=self.total_flops() / 1e9,
            gbytes=self.total_bytes() / 1e9,
            pinned=sum(1 for n in self.nodes if n.pinned is not None),
        )


_ELEMENTWISE_LIKE = frozenset({
    "add", "sub", "mul", "div", "max", "min", "pow", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "neg", "sign", "abs", "floor", "ceil",
    "convert_element_type", "reshape", "broadcast_in_dim", "transpose",
    "squeeze", "slice", "concatenate", "select_n", "stop_gradient",
    "integer_pow", "erf", "expand_dims", "rem", "and", "or", "not", "xor",
    "eq", "ne", "lt", "le", "gt", "ge", "iota", "clamp", "cos", "sin",
    "cumsum", "cumprod", "copy", "pad", "rev", "dynamic_slice",
    "dynamic_update_slice", "real", "imag", "is_finite", "square",
})


def _fusible(n: KernelNode) -> bool:
    return n.name in _ELEMENTWISE_LIKE and n.pinned is None
