"""Device catalog + roofline cost model.

The paper profiles each kernel on each GPU offline (§III-A).  We have no
heterogeneous hardware in this container, so kernel latency is derived from
the same roofline logic the paper uses to *explain* its measurements
(§II-C): ``t = max(flops / peak_flops_eff, bytes / hbm_bw_eff) + launch``.

Two catalogs are provided:
  * TPU types (the deployment target of this framework), and
  * the paper's own GPU table (Table I) so the paper's figures (kernel
    heterogeneity CDFs, cost-efficiency table) can be reproduced with the
    authors' hardware constants.

A measured-calibration hook lets real profiles override the analytic model
(`DeviceSpec.calibrate`), which is how this maps back onto the paper's
profile-then-plan flow on real clusters.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Mapping, Optional, Tuple

from repro.core.graph import KernelGraph, KernelNode


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One accelerator type. Units: FLOP/s, byte/s, bytes, $/hr."""

    name: str
    peak_flops: float              # dense bf16/fp16 tensor throughput
    vector_flops: float            # scalar/vector unit throughput (fp32)
    hbm_bw: float                  # HBM bandwidth
    hbm_bytes: float               # HBM capacity
    link_bw: float                 # per-link interconnect bandwidth
    link_latency: float = 1e-6     # base per-transfer latency (seconds)
    price: float = 1.0             # relative rental cost
    mxu_efficiency: float = 0.75   # achievable fraction of peak on GEMMs
    bw_efficiency: float = 0.85    # achievable fraction of HBM bandwidth
    launch_overhead: float = 2e-6  # fixed per-kernel dispatch cost
    # L2 / on-chip cache: kernels whose working set is cache-resident
    # run at l2_bw, not HBM bw.  This is the paper's own §II-C physics —
    # FlashAttention is fast on L40s *because* its tiles live in the
    # larger L2; devices with small caches spill.  Bandwidths are
    # public-microbenchmark estimates (see Table I for capacities).
    l2_bytes: float = 0.0
    l2_bw: float = 0.0
    # Core clock (GHz): short kernels are launch/ramp-latency bound, and
    # that latency scales inversely with clock — the paper's third
    # explanation for L40s/RTX wins on small (esp. decode) kernels.
    clock_ghz: float = 1.5

    # ------------------------------------------------------------------ #
    def kernel_time(self, node: KernelNode) -> float:
        """Roofline latency of one kernel on this device."""
        # Matrix-unit work runs at MXU speed; low-intensity work is
        # bandwidth-bound; everything else uses the vector unit.
        if node.name in _MXU_PRIMS and node.intensity > 4.0:
            compute = node.flops / (self.peak_flops * self.mxu_efficiency)
        else:
            compute = node.flops / (self.vector_flops * self.mxu_efficiency)
        bw = self.hbm_bw
        if self.l2_bytes and node.bytes_accessed <= self.l2_bytes:
            bw = max(bw, self.l2_bw)
        memory = node.bytes_accessed / (bw * self.bw_efficiency)
        # flops/bytes are TOTALS across node.repeat launches (decode
        # iterations); fixed dispatch latency is paid per launch.
        launch = self.launch_overhead * 1.5 / self.clock_ghz
        return max(compute, memory) + launch * node.repeat

    def transfer_time(self, nbytes: float, peer: "DeviceSpec",
                      bw_override: Optional[float] = None,
                      repeat: int = 1) -> float:
        """``nbytes`` is the TOTAL across ``repeat`` transfers (decode
        iterations); per-transfer base latency is paid per launch."""
        bw = bw_override if bw_override else min(self.link_bw, peer.link_bw)
        return self.link_latency * repeat + nbytes / bw

    def calibrate(self, measured: Mapping[Tuple, float]) -> "CalibratedDevice":
        return CalibratedDevice(self, dict(measured))


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Measured wall/model service-time ratios for one serving host.

    Fitted from the machine-readable ``CALIBRATION {json}`` line that
    ``examples/serve_pipeline.py`` emits (modeled vs wall-clock TTFT /
    TPOT for the same plan): the TTFT ratio calibrates prefill-phase
    kernels, the TPOT ratio decode-phase kernels, and their geometric
    mean everything untagged.  One factor per phase is all a single
    end-to-end measurement can support — per-kernel measured profiles
    go through :meth:`DeviceSpec.calibrate` instead.
    """
    prefill_scale: float = 1.0      # wall TTFT / modeled TTFT
    decode_scale: float = 1.0       # wall TPOT / modeled TPOT

    def __post_init__(self):
        if self.prefill_scale <= 0.0 or self.decode_scale <= 0.0:
            raise ValueError("calibration scales must be positive, got "
                             f"{self.prefill_scale}/{self.decode_scale}")

    @property
    def scale(self) -> float:
        """Phase-agnostic factor (geometric mean of the two ratios)."""
        return math.sqrt(self.prefill_scale * self.decode_scale)

    def apply(self, dev) -> "ScaledDevice":
        return ScaledDevice(dev, self)

    def apply_all(self, devices) -> "list[ScaledDevice]":
        return [self.apply(d) for d in devices]


def calibrate(calibration_json) -> Calibration:
    """Fit a :class:`Calibration` from a ``CALIBRATION`` payload.

    Accepts the parsed dict, a JSON string, or the raw log line (the
    leading ``CALIBRATION `` tag is stripped).  Recognized keys are the
    ones ``examples/serve_pipeline.py`` emits —
    ``ttft_wall_over_model`` / ``tpot_wall_over_model`` — with
    ``prefill_scale`` / ``decode_scale`` accepted as spelled-out
    aliases (the form ``DeploymentSpec.calibration`` round-trips).
    """
    import json
    if isinstance(calibration_json, (str, bytes)):
        s = calibration_json.strip()
        if isinstance(s, bytes):
            s = s.decode()
        if s.startswith("CALIBRATION"):
            s = s[len("CALIBRATION"):].strip()
        obj = json.loads(s)
    else:
        obj = dict(calibration_json)
    if not isinstance(obj, dict):
        raise ValueError(f"calibration payload must be an object, "
                         f"got {type(obj).__name__}")
    pre = obj.get("ttft_wall_over_model", obj.get("prefill_scale"))
    dec = obj.get("tpot_wall_over_model", obj.get("decode_scale"))
    if pre is None and dec is None:
        raise ValueError(
            "calibration payload carries neither ttft_wall_over_model "
            f"nor tpot_wall_over_model: {sorted(obj)}")
    return Calibration(prefill_scale=float(pre if pre is not None else 1.0),
                       decode_scale=float(dec if dec is not None else 1.0))


class ScaledDevice:
    """DeviceSpec whose analytic kernel times are scaled by a measured
    :class:`Calibration` — phase-aware: prefill kernels by the TTFT
    ratio, decode kernels by the TPOT ratio, untagged kernels by the
    geometric mean.  Transfer times are NOT scaled (the calibration
    line measures compute service, not the fabric).  The derived
    ``name`` keeps calibrated placements out of the uncalibrated
    plan-cache slot (the planner keys plans by device names).
    """

    def __init__(self, spec, cal: Calibration):
        self.spec = spec
        self.cal = cal
        self.name = (f"{spec.name}*cal{cal.prefill_scale:.4g}"
                     f"/{cal.decode_scale:.4g}")

    def __getattr__(self, item):
        return getattr(self.spec, item)

    def kernel_time(self, node: KernelNode) -> float:
        t = self.spec.kernel_time(node)
        if node.phase == "prefill":
            return t * self.cal.prefill_scale
        if node.phase == "decode":
            return t * self.cal.decode_scale
        return t * self.cal.scale

    def transfer_time(self, nbytes, peer, bw_override=None, repeat=1):
        return self.spec.transfer_time(nbytes, peer, bw_override, repeat)


class CalibratedDevice:
    """DeviceSpec whose kernel times are overridden by measured profiles.

    ``measured`` maps ``KernelNode.signature()`` -> seconds.  Unmeasured
    kernels fall back to the analytic roofline.  This is the adapter for
    the paper's offline profiling pass when real hardware is available.
    """

    def __init__(self, spec: DeviceSpec, measured: Dict[Tuple, float]):
        self.spec = spec
        self.measured = measured
        self.name = spec.name + "+cal"

    def __getattr__(self, item):
        return getattr(self.spec, item)

    def kernel_time(self, node: KernelNode) -> float:
        t = self.measured.get(node.signature())
        return t if t is not None else self.spec.kernel_time(node)

    def transfer_time(self, nbytes, peer, bw_override=None, repeat=1):
        return self.spec.transfer_time(nbytes, peer, bw_override, repeat)


# --------------------------------------------------------------------- #
# TPU catalog (deployment target).  Peak numbers are public roofline
# constants; v5e matches the dry-run hardware constants mandated for the
# roofline analysis (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
# --------------------------------------------------------------------- #
TPU_V5E = DeviceSpec("tpu-v5e", peak_flops=197e12, vector_flops=12e12,
                     hbm_bw=819e9, hbm_bytes=16e9, link_bw=50e9, price=1.0)
TPU_V5P = DeviceSpec("tpu-v5p", peak_flops=459e12, vector_flops=25e12,
                     hbm_bw=2765e9, hbm_bytes=95e9, link_bw=100e9, price=3.2)
TPU_V4 = DeviceSpec("tpu-v4", peak_flops=275e12, vector_flops=17e12,
                    hbm_bw=1228e9, hbm_bytes=32e9, link_bw=50e9, price=2.1)
TPU_V6E = DeviceSpec("tpu-v6e", peak_flops=918e12, vector_flops=40e12,
                     hbm_bw=1640e9, hbm_bytes=32e9, link_bw=90e9, price=2.3)

# --------------------------------------------------------------------- #
# Paper Table I GPU catalog (for reproducing the paper's own figures).
# CUDA core TFLOPS -> vector_flops (fp32); Tensor core -> peak_flops (bf16).
# Prices normalized by L40s, as in the paper.
# --------------------------------------------------------------------- #
GPU_A100 = DeviceSpec("a100", peak_flops=312e12, vector_flops=19.5e12,
                      hbm_bw=1935e9, hbm_bytes=80e9, link_bw=25e9,
                      price=1.5, l2_bytes=40e6, l2_bw=4500e9, clock_ghz=1.41)
GPU_H100 = DeviceSpec("h100", peak_flops=989e12, vector_flops=67e12,
                      hbm_bw=3350e9, hbm_bytes=80e9, link_bw=50e9,
                      price=2.9, l2_bytes=50e6, l2_bw=7000e9, clock_ghz=1.98)
GPU_B200 = DeviceSpec("b200", peak_flops=2500e12, vector_flops=80e12,
                      hbm_bw=8000e9, hbm_bytes=192e9, link_bw=50e9,
                      price=5.0, l2_bytes=126e6, l2_bw=12000e9, clock_ghz=2.1)
GPU_L40S = DeviceSpec("l40s", peak_flops=366.5e12, vector_flops=91.6e12,
                      hbm_bw=864e9, hbm_bytes=48e9, link_bw=25e9,
                      price=1.0, l2_bytes=96e6, l2_bw=4200e9, clock_ghz=2.52)
GPU_RTX6000 = DeviceSpec("rtxpro6000", peak_flops=500e12,
                         vector_flops=120e12, hbm_bw=1597e9,
                         hbm_bytes=96e9, link_bw=25e9, price=1.2,
                         l2_bytes=126e6, l2_bw=6000e9, clock_ghz=2.6)

CATALOG: Dict[str, DeviceSpec] = {
    d.name: d for d in [
        TPU_V5E, TPU_V5P, TPU_V4, TPU_V6E,
        GPU_A100, GPU_H100, GPU_B200, GPU_L40S, GPU_RTX6000,
    ]
}

# Heterogeneous pairs used throughout benchmarks, mirroring the paper's
# local setup (A100+L40s, H100+RTX Pro 6000, B200+H100) and the TPU-native
# pairings this framework targets.
PAPER_PAIRS = [("a100", "l40s"), ("h100", "rtxpro6000"), ("b200", "h100")]
TPU_PAIRS = [("tpu-v5p", "tpu-v5e"), ("tpu-v6e", "tpu-v5e"),
             ("tpu-v4", "tpu-v5e")]

_MXU_PRIMS = frozenset({
    "dot_general", "conv_general_dilated", "mixtral_moe_gmm",
    "flash_attention", "ragged_dot",
})


# --------------------------------------------------------------------- #
# Graph-level helpers used by planner / simulator / benchmarks.
# --------------------------------------------------------------------- #
def cost_matrix(graph: KernelGraph, devices) -> "list[list[float]]":
    """t[k][g]: latency of kernel k on device g (paper's t_{k,g})."""
    return [[dev.kernel_time(n) for dev in devices] for n in graph.nodes]


def edge_cost(nbytes: float, src_dev, dst_dev,
              bw_override: Optional[float] = None,
              repeat: int = 1) -> float:
    """Paper's c_ij^{u,g} = l_{u,g} + d_ij / bw_{u,g}."""
    return src_dev.transfer_time(nbytes, dst_dev, bw_override, repeat)


def graph_time_on(graph: KernelGraph, dev) -> float:
    """Total serial execution time of the whole graph on one device."""
    return sum(dev.kernel_time(n) for n in graph.nodes)
