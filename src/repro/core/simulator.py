"""Discrete-event simulator of disaggregated pipelined execution.

The container has no heterogeneous hardware, so the paper's performance
experiments (offline throughput, online latency, pipeline ablation,
bandwidth robustness, monitor sensitivity) are reproduced on a
discrete-event model driven by the *same* cost model the planner uses:

  * one compute server per device (stages serialize on it),
  * one ingress-link server per device (cut-edge transfers serialize on
    it, the paper's receiver-side M_g),
  * compute and communication on a device overlap (separate servers) —
    the premise of the paper's pipelined execution model,
  * scheduling: "priority" (oldest request first — the paper's
    priority-aware streams) or "fifo" (naive multi-streaming),
  * pipelining off = one request admitted at a time.

Simulated time is deterministic; no wall clocks are read.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
from itertools import repeat
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import KernelGraph
from repro.core.planner import Plan
from repro.core.monitor import MonitorConfig, OnlineMonitor


@dataclasses.dataclass
class StageTask:
    """Per-request instance of a plan stage."""
    stage_idx: int
    device: int
    compute: float
    ingress: float          # serialized transfer time on the ingress link


def stage_tasks(graph: KernelGraph, plan: Plan, devices,
                bw_override: Optional[float] = None) -> List[StageTask]:
    tasks = []
    for st in plan.stages:
        nset = set(st.node_ids)
        ingress = 0.0
        for (i, j), b in graph.edges.items():
            if j in nset and plan.labels[i] != st.device:
                rep = max(graph.nodes[i].repeat, graph.nodes[j].repeat)
                ingress += devices[plan.labels[i]].transfer_time(
                    b, devices[st.device], bw_override, repeat=rep)
        tasks.append(StageTask(st.idx, st.device, st.compute_time, ingress))
    # recompute stage compute under (possibly) overridden devices
    for t, st in zip(tasks, plan.stages):
        t.compute = sum(devices[st.device].kernel_time(graph.nodes[k])
                        for k in st.node_ids)
    return tasks


@dataclasses.dataclass
class SimResult:
    makespan: float
    completed: int
    latencies: List[float]
    device_busy: List[float]        # compute-busy seconds per device
    link_busy: List[float]          # ingress-busy seconds per device
    switches: int = 0
    # Dispatch log: (kind, device, request, start, end) per scheduled
    # unit, in dispatch order.  Simulated time is pure arithmetic on the
    # inputs, so two runs with identical seed+trace+plan must produce
    # bit-identical logs (tests/test_monitor_sim.py asserts this).
    events: List[Tuple[int, int, int, float, float]] = \
        dataclasses.field(default_factory=list)

    @property
    def throughput(self) -> float:
        return self.completed / max(self.makespan, 1e-12)

    @property
    def mean_latency(self) -> float:
        return sum(self.latencies) / max(len(self.latencies), 1)

    def p(self, q: float) -> float:
        xs = sorted(self.latencies)
        if not xs:
            return 0.0
        return xs[min(int(q * len(xs)), len(xs) - 1)]

    def busy_fraction(self, dev: int) -> float:
        return self.device_busy[dev] / max(self.makespan, 1e-12)


# --------------------------------------------------------------------- #
class _DES:
    """Core event loop shared by offline and online modes."""

    def __init__(self, tasks: List[StageTask], num_devices: int,
                 scheduling: str = "priority", pipelined: bool = True,
                 max_inflight: int = 16):
        self.tasks = tasks
        self.nG = num_devices
        self.scheduling = scheduling
        self.pipelined = pipelined
        self.max_inflight = max_inflight if pipelined else 1

        self.dev_free = [0.0] * num_devices
        self.link_free = [0.0] * num_devices
        self.dev_busy = [0.0] * num_devices
        self.link_busy = [0.0] * num_devices

    def run(self, arrivals: List[float],
            iters_per_request: int = 1,
            stall_windows: Optional[List[Tuple[float, float]]] = None
            ) -> SimResult:
        """arrivals[r] = submit time of request r (must be sorted).

        Each stage is two independently-scheduled units — a transfer on
        the receiver's ingress link, then compute on the device — so the
        link and device queues pack independently (committing both at
        once reserves idle gaps and under-utilizes both)."""
        n = len(arrivals)
        events: List[Tuple[int, int, int, float, float]] = []
        # unit list: (kind 0=link/1=dev, device, duration)
        units: List[Tuple[int, int, float]] = []
        for t in self.tasks:
            if t.ingress > 0:
                units.append((0, t.device, t.ingress))
            units.append((1, t.device, t.compute))
        total_units = len(units) * iters_per_request
        cursor = [0] * n
        ready_at = [a for a in arrivals]
        finish = [0.0] * n
        admitted: List[int] = []
        waiting = list(range(n))
        done = 0
        stall_windows = stall_windows or []

        # list scheduling: repeatedly dispatch the frontier unit with the
        # earliest feasible start.
        #  priority   — ties broken by request age (stream priority:
        #               staggers communication phases),
        #  fifo/naive — equalize progress (models SM fair sharing: all
        #               streams reach their comm phases together).
        while done < n:
            while waiting and len(admitted) < self.max_inflight:
                admitted.append(waiting.pop(0))
            best, best_start, best_key = None, math.inf, None
            for r in admitted:
                kind, dev, dur = units[cursor[r] % len(units)]
                res_free = (self.link_free if kind == 0
                            else self.dev_free)[dev]
                start = max(ready_at[r], res_free)
                if self.scheduling == "priority":
                    key = (round(start, 12), r)
                else:
                    key = (cursor[r], round(start, 12), r)
                if best_key is None or key < best_key:
                    best, best_start, best_key = r, start, key
            r = best
            kind, dev, dur = units[cursor[r] % len(units)]
            start = best_start
            for (w0, w1) in stall_windows:          # policy-switch stalls
                if w0 <= start < w1:
                    start = w1
            end = start + dur
            if kind == 0:
                self.link_free[dev] = end
                self.link_busy[dev] += dur
            else:
                self.dev_free[dev] = end
                self.dev_busy[dev] += dur
            events.append((kind, dev, r, start, end))
            ready_at[r] = end
            cursor[r] += 1
            if cursor[r] >= total_units:
                finish[r] = end
                admitted.remove(r)
                done += 1

        makespan = max(finish) - min(arrivals) if n else 0.0
        lats = [finish[r] - arrivals[r] for r in range(n)]
        return SimResult(makespan=makespan, completed=n, latencies=lats,
                         device_busy=self.dev_busy,
                         link_busy=self.link_busy, events=events)


# --------------------------------------------------------------------- #
def simulate_offline(graph: KernelGraph, plan: Plan, devices,
                     num_requests: int = 64,
                     scheduling: str = "priority",
                     pipelined: bool = True,
                     max_inflight: int = 16,
                     iters_per_request: int = 1,
                     bw_override: Optional[float] = None) -> SimResult:
    """All requests available at t=0; throughput = N / makespan."""
    tasks = stage_tasks(graph, plan, devices, bw_override)
    des = _DES(tasks, len(devices), scheduling, pipelined, max_inflight)
    return des.run([0.0] * num_requests, iters_per_request)


def simulate_online(graph: KernelGraph, plans: Dict[str, Plan], devices,
                    rate: float, num_requests: int = 200,
                    monitor: Optional[OnlineMonitor] = None,
                    seed: int = 0,
                    iters_per_request: int = 4,
                    bw_override: Optional[float] = None) -> SimResult:
    """Poisson arrivals at ``rate`` req/s; optional monitor switches
    between the provided {"latency": plan, "throughput": plan}."""
    rng = random.Random(seed)
    arrivals = []
    t = 0.0
    for _ in range(num_requests):
        t += rng.expovariate(rate)
        arrivals.append(t)

    if monitor is None:
        plan = plans.get("latency") or next(iter(plans.values()))
        tasks = stage_tasks(graph, plan, devices, bw_override)
        des = _DES(tasks, len(devices), "priority", True, 16)
        return des.run(arrivals, iters_per_request)

    # Windowed re-simulation with policy switching: requests arriving in
    # each window run under the policy the monitor chose at its start.
    # Exec latency baseline = unqueued single-request pass.
    result_lats: List[float] = []
    switches = 0
    stalls: List[Tuple[float, float]] = []
    cur_sched = monitor.policy
    # exec-only latency per policy (no queueing)
    exec_lat = {}
    for name, plan in plans.items():
        tasks = stage_tasks(graph, plan, devices, bw_override)
        exec_lat[name] = sum(t0.compute + t0.ingress
                             for t0 in tasks) * iters_per_request

    # process sequentially, windowed
    W = monitor.cfg.window
    idx = 0
    clock = 0.0
    des = None
    pending: List[float] = []
    makespan = 0.0
    seen_switches = 0
    while idx < len(arrivals) or pending:
        w_end = clock + W
        batch = []
        while idx < len(arrivals) and arrivals[idx] < w_end:
            batch.append(arrivals[idx])
            idx += 1
        batch = pending + batch
        pending = []
        if batch:
            plan = plans[monitor.policy if monitor.policy in plans
                         else "latency"]
            tasks = stage_tasks(graph, plan, devices, bw_override)
            pl = monitor.policy == "throughput"
            des = _DES(tasks, len(devices), "priority",
                       pipelined=pl, max_inflight=16 if pl else 2)
            sub = des.run(batch, iters_per_request, stall_windows=stalls)
            for a, l in zip(batch, sub.latencies):
                result_lats.append(l)
                monitor.record_request(a + l, l,
                                       exec_lat[monitor.policy
                                                if monitor.policy in exec_lat
                                                else "latency"])
                makespan = max(makespan, a + l)
        monitor.tick(w_end)
        if monitor.switches > seen_switches:
            # each switch stalls workers at the next iteration boundary
            stalls.append((w_end, w_end + monitor.cfg.switch_stall *
                           (monitor.switches - seen_switches)))
            seen_switches = monitor.switches
        clock = w_end

    return SimResult(makespan=makespan, completed=len(result_lats),
                     latencies=result_lats,
                     device_busy=[0.0] * len(devices),
                     link_busy=[0.0] * len(devices),
                     switches=monitor.switches)


# ===================================================================== #
# Cluster composition: many replicas, each its own discrete-event model #
# ===================================================================== #
#
# A *replica* is one disaggregated device group executing one Plan (its
# own compute + ingress-link servers, exactly the single-replica model
# above).  The cluster simulator composes N replica models under a
# router: arrivals are processed in time order, the router picks a
# replica using only information available at the arrival instant
# (queue backlog, predicted service time), and the request's stage
# units are scheduled FCFS against that replica's resource timelines.
# Compute and communication still overlap (separate servers), and
# consecutive requests pipeline through the replica's stages.
#
# Per-request heterogeneity: stage-unit durations are scaled by how
# much longer/shorter the request's prompt and output are than the
# lengths the plan's DDG was traced with (prefill work ~ prompt tokens,
# decode work ~ output tokens).

@dataclasses.dataclass(frozen=True)
class ClusterRequest:
    """Router-visible request: scales are relative to the plan's DDG."""
    rid: int
    arrival: float
    scale_prompt: float = 1.0       # prefill work multiplier
    scale_output: float = 1.0       # decode work multiplier
    session: Optional[int] = None   # decode-session affinity key
    kv_bytes: float = 0.0           # prefill->decode KV handoff size
    slo: Optional[float] = None     # completion deadline (s of latency)
    slo_ttft: Optional[float] = None    # first-token deadline (s)
    priority: int = 0               # brown-out shedding order (higher
    #                                 survives longer; see router health)


def _phase_scales(req: ClusterRequest, phase: str) -> Tuple[float, float]:
    """(scale_prompt, scale_output) with the other phase zeroed out."""
    if phase == "both":
        return req.scale_prompt, req.scale_output
    if phase == "prefill":
        return req.scale_prompt, 0.0
    if phase == "decode":
        return 0.0, req.scale_output
    raise ValueError(f"unknown phase {phase!r}")


@dataclasses.dataclass
class Interconnect:
    """Cross-replica fabric for KV-state handoff.

    ``default_bw`` models the datacenter fabric between replica groups
    (distinct from the intra-replica ``DeviceSpec.link_bw`` the planner
    cuts over); ``bw[(src, dst)]`` overrides individual directed pairs —
    the "bandwidth matrix" knob for rack-locality experiments.
    """
    default_bw: float = 100e9       # bytes/s between replica groups
    base_latency: float = 20e-6     # per-transfer setup cost (s)
    bw: Dict[Tuple[int, int], float] = \
        dataclasses.field(default_factory=dict)

    def bandwidth(self, src: int, dst: int) -> float:
        return self.bw.get((src, dst), self.default_bw)

    def transfer_time(self, nbytes: float, src: int, dst: int) -> float:
        if src == dst or nbytes <= 0.0:
            return 0.0
        return self.base_latency + nbytes / self.bandwidth(src, dst)


@dataclasses.dataclass(frozen=True)
class ReplicaUnit:
    kind: int           # 0 = ingress link, 1 = compute
    device: int         # replica-local device index
    duration: float     # seconds at scale 1.0
    decode_frac: float  # fraction of the unit scaled by scale_output

    def scaled(self, scale_prompt: float, scale_output: float) -> float:
        return self.duration * (self.decode_frac * scale_output
                                + (1.0 - self.decode_frac) * scale_prompt)


def replica_units(graph: KernelGraph, plan: Plan, devices,
                  bw_override: Optional[float] = None) -> List[ReplicaUnit]:
    """Stage tasks -> schedulable units with decode fractions."""
    units: List[ReplicaUnit] = []
    for task, stage in zip(stage_tasks(graph, plan, devices, bw_override),
                           plan.stages):
        comp_total = sum(devices[stage.device].kernel_time(graph.nodes[k])
                         for k in stage.node_ids)
        comp_decode = sum(devices[stage.device].kernel_time(graph.nodes[k])
                          for k in stage.node_ids
                          if graph.nodes[k].phase == "decode")
        frac = comp_decode / comp_total if comp_total > 0 else 0.0
        if task.ingress > 0:
            units.append(ReplicaUnit(0, task.device, task.ingress, frac))
        units.append(ReplicaUnit(1, task.device, task.compute, frac))
    return units


class UnitProgram:
    """Compiled structure-of-arrays form of one stage-unit list.

    ``ReplicaUnit.scaled`` is affine in ``(scale_prompt,
    scale_output)``, so a whole unit list reduces to three preallocated
    float64 arrays (duration, decode fraction, prefill fraction) plus
    the per-unit ``(kind, device)`` routing the walk needs.  Two things
    fall out of the compilation:

      * ``predicted_service`` / ``predicted_phase_service`` become the
        cached dot products ``sp * svc_pre + so * svc_dec`` — O(1) per
        routing probe instead of re-summing the unit list for every
        candidate group of every request;
      * the walk's per-unit durations come from ONE elementwise numpy
        expression over the arrays (``dur * (frac*so + omf*sp)``),
        which is bit-identical to calling ``scaled`` per unit because
        float64 ufuncs apply the same IEEE operations elementwise.

    Small unit lists (the common case: a handful of plan stages) fall
    below numpy's per-call overhead, so the walk evaluates the same
    affine expression in scalar Python under ``_VECTOR_MIN`` units —
    identical bits either way.

    Programs are cached process-wide by unit-list *content* (not
    identity — list ids can be recycled), so sizing-search candidates
    that share group templates reuse compiled programs across every
    DES replay.
    """

    __slots__ = ("n", "dur", "frac", "omf", "steps", "svc_pre",
                 "svc_dec", "_walk_plans")

    def __init__(self, units: Sequence[ReplicaUnit]):
        self._walk_plans: Dict[str, _WalkPlan] = {}
        self.n = len(units)
        self.dur = np.array([u.duration for u in units],
                            dtype=np.float64)
        self.frac = np.array([u.decode_frac for u in units],
                             dtype=np.float64)
        self.omf = 1.0 - self.frac      # prefill fraction, elementwise
        # (kind, device, has_prefill_share, duration, frac, omf) —
        # plain tuples so the scheduling loop stays attribute-free
        self.steps = [(u.kind, u.device, u.decode_frac < 1.0,
                       u.duration, u.decode_frac, 1.0 - u.decode_frac)
                      for u in units]
        # predicted_service(sp, so) == sp * svc_pre + so * svc_dec
        self.svc_pre = float(np.dot(self.dur, self.omf))
        self.svc_dec = float(np.dot(self.dur, self.frac))

    def service(self, sp: float, so: float) -> float:
        return sp * self.svc_pre + so * self.svc_dec

    def durations(self, sp: float, so: float) -> List[float]:
        """Per-unit ``scaled(sp, so)``, bit-identical to the loop."""
        if self.n < _VECTOR_MIN:
            return [d * (f * so + o * sp)
                    for _, _, _, d, f, o in self.steps]
        return (self.dur * (self.frac * so + self.omf * sp)).tolist()

    def walk_plan(self, phase: str) -> "_WalkPlan":
        wp = self._walk_plans.get(phase)
        if wp is None:
            wp = self._walk_plans[phase] = _WalkPlan(self, phase)
        return wp


class _WalkPlan:
    """Request-independent structure of one program's walk for one
    phase: which units run, and where the walk can actually *wait*.

    Along a walk the clock ``t`` is strictly increasing (every active
    unit has ``dur > 0``), and a resource's free timeline is only
    rewritten BY this walk to the then-current ``t``.  So ``max(t,
    free)`` can exceed ``t`` only at the FIRST active unit of each
    ``(kind, device)`` resource — everywhere else it returns ``t``
    exactly.  That turns the per-unit scheduling loop into one seeded
    ``np.cumsum`` per resource segment (numpy's cumsum accumulates
    sequentially, so the ends match the reference walk's chain of
    additions bit-for-bit), with busy/aggregate accumulators seeded the
    same way.

    Which units are active is request-independent: phase scales are
    strictly positive for the phases a request carries, so ``scaled(sp,
    so) > 0`` reduces to a predicate on the unit's stored duration and
    decode fraction.
    """

    __slots__ = ("n", "dur", "frac", "omf", "kinds", "devs",
                 "seg_bounds", "seg_res", "res_groups",
                 "pe_pos", "pe_dur", "pe_frac", "pe_omf")

    def __init__(self, prog: UnitProgram, phase: str):
        if phase == "prefill":          # so == 0: runs iff omf > 0
            mask = (prog.dur > 0.0) & (prog.omf > 0.0)
        elif phase == "decode":         # sp == 0: runs iff frac > 0
            mask = (prog.dur > 0.0) & (prog.frac > 0.0)
        else:                           # sp, so > 0: runs iff dur > 0
            mask = prog.dur > 0.0
        idx = np.nonzero(mask)[0]
        self.n = int(len(idx))
        self.dur = prog.dur[idx]
        self.frac = prog.frac[idx]
        self.omf = prog.omf[idx]
        steps = [prog.steps[i] for i in idx.tolist()]
        self.kinds = [s[0] for s in steps]
        self.devs = [s[1] for s in steps]
        # segment boundaries: a new segment at the first active
        # occurrence of each (kind, device) resource
        seg_bounds: List[int] = []
        seg_res: List[Tuple[int, int]] = []
        groups: Dict[Tuple[int, int], List[int]] = {}
        for p, r in enumerate(zip(self.kinds, self.devs)):
            ps = groups.get(r)
            if ps is None:
                groups[r] = [p]
                seg_bounds.append(p)
                seg_res.append(r)
            else:
                ps.append(p)
        seg_bounds.append(self.n)
        self.seg_bounds = seg_bounds
        self.seg_res = seg_res
        # per-resource positions (busy/free/aggregate updates)
        self.res_groups = [
            (k, d, np.asarray(ps, dtype=np.intp), ps[-1], len(ps))
            for (k, d), ps in groups.items()]
        # last active unit with a prefill share (TTFT anchor)
        pe = [p for p in range(self.n) if self.frac[p] < 1.0]
        if pe:
            self.pe_pos = pe[-1]
            self.pe_dur = float(self.dur[self.pe_pos])
            self.pe_frac = float(self.frac[self.pe_pos])
            self.pe_omf = float(self.omf[self.pe_pos])
        else:
            self.pe_pos = -1
            self.pe_dur = self.pe_frac = self.pe_omf = 0.0


#: below this many units the scalar path beats numpy's call overhead
_VECTOR_MIN = 24

#: below this many ACTIVE units the scalar walk loop beats the
#: segmented-cumsum walk's fixed numpy call overhead
_VECTOR_WALK_MIN = 48

_PROGRAM_CACHE: Dict[Tuple, UnitProgram] = {}


def compile_units(units: Sequence[ReplicaUnit]) -> UnitProgram:
    """Content-keyed process-wide program cache (plan-cache idiom)."""
    key = tuple((u.kind, u.device, u.duration, u.decode_frac)
                for u in units)
    prog = _PROGRAM_CACHE.get(key)
    if prog is None:
        prog = _PROGRAM_CACHE[key] = UnitProgram(units)
    return prog


@dataclasses.dataclass
class EventAggregate:
    """Reduction of a full event log: per ``(replica, kind, device)``
    dispatch counts and busy seconds.

    ``events="agg"`` runs keep exactly this instead of the per-unit
    tuple list (the tuples dominate memory at 1M requests); the
    accumulation order matches the append order of a full log, and each
    event contributes ``t1 - t0`` (not its pre-rounding duration), so
    ``EventAggregate.from_events(full_log)`` equals the aggregate an
    ``events="agg"`` run produced — bit-identically (tested).

    KV transfers aggregate under ``(dst_replica, KV_TRANSFER,
    src_replica)``, mirroring their event-tuple layout.
    """

    counts: Dict[Tuple[int, int, int], int] = \
        dataclasses.field(default_factory=dict)
    seconds: Dict[Tuple[int, int, int], float] = \
        dataclasses.field(default_factory=dict)

    def add(self, rep: int, kind: int, dev: int,
            t0: float, t1: float) -> None:
        key = (rep, kind, dev)
        counts = self.counts
        if key in counts:
            counts[key] += 1
            self.seconds[key] += t1 - t0
        else:
            counts[key] = 1
            self.seconds[key] = t1 - t0

    @property
    def total_events(self) -> int:
        return sum(self.counts.values())

    @property
    def total_seconds(self) -> float:
        return sum(self.seconds.values())

    @classmethod
    def from_events(cls, events: Sequence[Tuple]) -> "EventAggregate":
        agg = cls()
        for rep, _rid, kind, dev, t0, t1 in events:
            agg.add(rep, kind, dev, t0, t1)
        return agg


class ReplicaModel:
    """Incremental discrete-event model of one replica.

    Unlike :class:`_DES` (which needs the full arrival list up front),
    requests are submitted one at a time so a router can interleave
    scheduling decisions with queue evolution.  Each resource is a FCFS
    server; a submitted request walks its stage units in topological
    order, starting each unit at max(previous unit end, resource free).

    Two walk implementations share this state:

      * the default fast path executes the compiled
        :class:`UnitProgram` (scoring via cached dot products, O(1)
        ``backlog`` from a running free-timeline maximum),
      * ``reference=True`` restores the historical per-unit object walk
        (``_run_units_reference``), O(n) scoring and O(n) backlog scans
        — the honest "before" for benchmarks and the oracle the parity
        suite checks bit-identical event logs against.

    ``track_inflight=False`` drops the per-request finish-heap push
    (``queue_len`` bookkeeping) — the deployment DES disables it when
    no controller will ever call ``queue_len``.
    """

    def __init__(self, idx: int, num_devices: int,
                 unit_sets: Dict[str, List[ReplicaUnit]],
                 policy: str = "latency",
                 monitor: Optional[OnlineMonitor] = None,
                 price: float = 0.0):
        assert policy in unit_sets, f"no unit set for policy {policy!r}"
        self.idx = idx
        self.num_devices = num_devices
        self.unit_sets = unit_sets
        self.programs = {pol: compile_units(us)
                         for pol, us in unit_sets.items()}
        self.policy = policy
        self.monitor = monitor
        self.price = price              # $/hr of this device group
        self.reference = False          # historical walk + O(n) probes
        self.track_inflight = True      # maintain the queue_len heap
        # Routability flag owned by the deployment control timeline:
        # warm-up ("up" pending), drain ("down") and failure ("fail")
        # all mask the group by flipping this; routers skip ineligible
        # groups (see serving/router.py).
        self.eligible = True
        # Transient straggle multiplier owned by "slow" control events:
        # every stage unit (and the service predictions routers probe)
        # runs `slow` x longer while a window is open.  Exactly 1.0
        # outside windows; the walks guard on `!= 1.0` so fault-free
        # runs evaluate the identical float expressions.
        self.slow = 1.0
        self.dev_free = [0.0] * num_devices
        self.link_free = [0.0] * num_devices
        self.dev_busy = [0.0] * num_devices
        self.link_busy = [0.0] * num_devices
        self._max_free = 0.0            # == max(dev_free + link_free)
        self.completed = 0
        self.switches = 0
        self._finish: List[float] = []          # heap of inflight finishes

    # -------------------------------------------------------------- #
    def predicted_service(self, req: ClusterRequest,
                          policy: Optional[str] = None) -> float:
        """Unqueued execution latency of ``req`` on this replica."""
        sp, so = req.scale_prompt, req.scale_output
        if self.slow != 1.0:
            sp *= self.slow
            so *= self.slow
        if self.reference:
            units = self.unit_sets[policy or self.policy]
            return sum(u.scaled(sp, so) for u in units)
        return self.programs[policy or self.policy].service(sp, so)

    def backlog(self, now: float) -> float:
        """Seconds until the most-loaded resource drains (queue delay
        proxy: a new request cannot finish before its bottleneck
        resource frees up).  The fast path keeps a running maximum —
        free timelines only ever move forward — so a router probe is
        O(1) instead of rescanning both free-lists."""
        if self.reference:
            worst = max(max(self.dev_free), max(self.link_free))
        else:
            worst = self._max_free
        return max(0.0, worst - now)

    def queue_len(self, now: float) -> int:
        while self._finish and self._finish[0] <= now:
            heapq.heappop(self._finish)
        return len(self._finish)

    def predicted_phase_service(self, req: ClusterRequest,
                                phase: str,
                                policy: Optional[str] = None) -> float:
        """Unqueued latency of one phase of ``req`` on this replica.

        Phase filtering reuses each unit's decode fraction: the prefill
        phase runs the unit at ``scale_output=0`` and the decode phase at
        ``scale_prompt=0``, so prefill + decode == the colocated total.
        """
        sp, so = _phase_scales(req, phase)
        if self.slow != 1.0:
            sp *= self.slow
            so *= self.slow
        if self.reference:
            units = self.unit_sets[policy or self.policy]
            return sum(u.scaled(sp, so) for u in units)
        return self.programs[policy or self.policy].service(sp, so)

    # -------------------------------------------------------------- #
    def submit(self, req: ClusterRequest,
               events: Optional[List[Tuple]] = None, *,
               phase: str = "both",
               not_before: float = 0.0) -> float:
        """Schedule the request (or one phase of it); returns its finish
        time.  ``phase`` selects which share of each stage unit runs
        here: "both" (colocated), "prefill" (decode share zeroed) or
        "decode" (prefill share zeroed — a decode_only admission that
        starts from imported KV state).  ``not_before`` delays the first
        unit (KV-transfer arrival, rate-matched admission)."""
        return self._run_units(req, events, phase, not_before)[0]

    def _run_units(self, req: ClusterRequest,
                   events: Optional[List[Tuple]] = None,
                   phase: str = "both",
                   not_before: float = 0.0,
                   agg: Optional[EventAggregate] = None
                   ) -> Tuple[float, float, float]:
        """Walk the request's stage units; returns ``(finish,
        prefill_end, start)`` where ``prefill_end`` is when the last
        unit with any prefill share completes (the first token's
        timestamp for a colocated or prefill-phase submission) and
        ``start`` is when the first unit actually began (after
        queueing) — the anchor chunked KV streaming interpolates
        production progress from."""
        if self.reference:
            return self._run_units_reference(req, events, phase,
                                             not_before, agg)
        return self._run_units_program(req, events, phase,
                                       not_before, agg)

    def _run_units_program(self, req: ClusterRequest,
                           events: Optional[List[Tuple]],
                           phase: str, not_before: float,
                           agg: Optional[EventAggregate]
                           ) -> Tuple[float, float, float]:
        """Fast walk over the compiled program.  Bit-identical to
        ``_run_units_reference``: every arithmetic expression below is
        the same IEEE float64 expression the reference walk evaluates
        per unit (the parity suite asserts equal event logs)."""
        sp, so = _phase_scales(req, phase)
        if self.slow != 1.0:        # open straggle window
            sp *= self.slow
            so *= self.slow
        prog = self.programs[self.policy]
        if prog.n >= _VECTOR_WALK_MIN:
            wp = prog.walk_plan(phase)
            if wp.n >= _VECTOR_WALK_MIN:
                return self._run_units_vector(req, events, phase,
                                              not_before, agg, wp,
                                              sp, so)
        durs = prog.durations(sp, so)
        t = req.arrival
        if not_before > t:
            t = not_before
        prefill_end = t
        start_t: Optional[float] = None
        dev_free = self.dev_free
        link_free = self.link_free
        dev_busy = self.dev_busy
        link_busy = self.link_busy
        idx = self.idx
        rid = req.rid
        append = events.append if events is not None else None
        agg_add = agg.add if agg is not None else None
        for step, dur in zip(prog.steps, durs):
            if dur <= 0.0:
                continue            # unit fully belongs to the other phase
            kind, dev, pre_share, u_dur, u_frac, u_omf = step
            if kind == 0:
                start = link_free[dev]
                if t > start:
                    start = t
                end = start + dur
                link_free[dev] = end
                link_busy[dev] += dur
            else:
                start = dev_free[dev]
                if t > start:
                    start = t
                end = start + dur
                dev_free[dev] = end
                dev_busy[dev] += dur
            if start_t is None:
                start_t = start
            if append is not None:
                append((idx, rid, kind, dev, start, end))
            elif agg_add is not None:
                agg_add(idx, kind, dev, start, end)
            t = end
            if pre_share:
                # the unit's prefill share finishes first; its decode
                # share (repeated decode iterations) follows — a
                # request's own decode work cannot precede its first
                # token, so TTFT charges only the prefill share here
                prefill_end = start + u_dur * (u_frac * 0.0
                                               + u_omf * sp)
        if start_t is not None and t > self._max_free:
            # ends are monotone along the walk, so the final t is the
            # max the free timelines moved to
            self._max_free = t
        if self.track_inflight:
            heapq.heappush(self._finish, t)
        if phase != "prefill":      # the decode side owns completion
            self.completed += 1
        return t, prefill_end, (start_t if start_t is not None else t)

    def _run_units_vector(self, req: ClusterRequest,
                          events: Optional[List[Tuple]],
                          phase: str, not_before: float,
                          agg: Optional[EventAggregate],
                          wp: "_WalkPlan", sp: float, so: float
                          ) -> Tuple[float, float, float]:
        """Segmented-cumsum walk for long programs: the per-unit loop
        collapses to one seeded ``np.cumsum`` per resource segment (see
        ``_WalkPlan``); busy cells and aggregates accumulate through
        seeded cumsums too, so every value matches the per-unit walk
        bit-for-bit while the Python work scales with the number of
        distinct resources, not units."""
        durs = wp.dur * (wp.frac * so + wp.omf * sp)
        t = req.arrival
        if not_before > t:
            t = not_before
        t0v = t
        A = wp.n
        bounds = wp.seg_bounds
        dev_free = self.dev_free
        link_free = self.link_free
        ends = np.empty(A)
        head_starts: List[float] = []
        for j, (k, d) in enumerate(wp.seg_res):
            a = bounds[j]
            b = bounds[j + 1]
            free = link_free[d] if k == 0 else dev_free[d]
            start = free if free > t else t
            head_starts.append(start)
            seg = np.cumsum(np.concatenate(([start], durs[a:b])))
            ends[a:b] = seg[1:]
            t = float(seg[-1])
        starts = np.empty(A)
        starts[1:] = ends[:-1]
        for j, p in enumerate(bounds[:-1]):
            starts[p] = head_starts[j]
        dev_busy = self.dev_busy
        link_busy = self.link_busy
        for k, d, pos, last, cnt in wp.res_groups:
            end_last = float(ends[last])
            if k == 0:
                link_free[d] = end_last
                link_busy[d] = float(np.cumsum(np.concatenate(
                    ([link_busy[d]], durs[pos])))[-1])
            else:
                dev_free[d] = end_last
                dev_busy[d] = float(np.cumsum(np.concatenate(
                    ([dev_busy[d]], durs[pos])))[-1])
        if events is not None:
            events.extend(zip(repeat(self.idx), repeat(req.rid),
                              wp.kinds, wp.devs,
                              starts.tolist(), ends.tolist()))
        elif agg is not None:
            spans = ends - starts
            counts = agg.counts
            seconds = agg.seconds
            ridx = self.idx
            for k, d, pos, last, cnt in wp.res_groups:
                key = (ridx, k, d)
                if key in counts:
                    counts[key] += cnt
                    seed = seconds[key]
                else:
                    counts[key] = cnt
                    seed = 0.0
                seconds[key] = float(np.cumsum(np.concatenate(
                    ([seed], spans[pos])))[-1])
        if t > self._max_free:
            self._max_free = t
        if self.track_inflight:
            heapq.heappush(self._finish, t)
        if phase != "prefill":      # the decode side owns completion
            self.completed += 1
        if wp.pe_pos >= 0:
            prefill_end = float(starts[wp.pe_pos]) + wp.pe_dur * (
                wp.pe_frac * 0.0 + wp.pe_omf * sp)
        else:
            prefill_end = t0v
        return t, prefill_end, head_starts[0]

    def _run_units_reference(self, req: ClusterRequest,
                             events: Optional[List[Tuple]],
                             phase: str, not_before: float,
                             agg: Optional[EventAggregate] = None
                             ) -> Tuple[float, float, float]:
        """The historical per-unit object walk (PR 2's
        ``call_reference`` idiom): kept verbatim as the oracle the fast
        path must reproduce bit-identically, and as the honest
        "before" of benchmarks/des_throughput.py."""
        sp, so = _phase_scales(req, phase)
        if self.slow != 1.0:        # open straggle window
            sp *= self.slow
            so *= self.slow
        t = max(req.arrival, not_before)
        prefill_end = t
        start_t: Optional[float] = None
        for u in self.unit_sets[self.policy]:
            dur = u.scaled(sp, so)
            if dur <= 0.0:
                continue            # unit fully belongs to the other phase
            free = self.link_free if u.kind == 0 else self.dev_free
            busy = self.link_busy if u.kind == 0 else self.dev_busy
            start = max(t, free[u.device])
            if start_t is None:
                start_t = start
            end = start + dur
            free[u.device] = end
            busy[u.device] += dur
            if events is not None:
                events.append((self.idx, req.rid, u.kind, u.device,
                               start, end))
            elif agg is not None:
                agg.add(self.idx, u.kind, u.device, start, end)
            t = end
            if u.decode_frac < 1.0:
                prefill_end = start + u.scaled(sp, 0.0)
        if start_t is not None and t > self._max_free:
            self._max_free = t
        heapq.heappush(self._finish, t)
        if phase != "prefill":      # the decode side owns completion
            self.completed += 1
        return t, prefill_end, (start_t if start_t is not None else t)

    def maybe_switch(self, now: float) -> bool:
        """Adopt the monitor's policy; a switch stalls all workers for
        ``switch_stall`` at the next iteration boundary (modeled as a
        bump of every resource timeline)."""
        if self.monitor is None or self.monitor.policy == self.policy:
            return False
        if self.monitor.policy not in self.unit_sets:
            return False
        self.policy = self.monitor.policy
        stall = self.monitor.cfg.switch_stall
        for free in (self.dev_free, self.link_free):
            for d in range(self.num_devices):
                free[d] = max(free[d], now) + stall
        self._max_free = max(max(self.dev_free), max(self.link_free))
        self.switches += 1
        return True


@dataclasses.dataclass
class ClusterResult:
    makespan: float
    completed: int
    latencies: List[float]              # served requests, arrival order
    assignments: List[int]              # replica per request (-1 = shed)
    per_replica_completed: List[int]
    per_replica_busy: List[float]       # summed compute-busy seconds
    switches: int
    events: List[Tuple]                 # (replica, rid, kind, dev, t0, t1)
    price_rate: float = 0.0             # $/hr of all device groups
    ttfts: List[float] = dataclasses.field(default_factory=list)
    shed: int = 0                       # admission-control rejections
    slo_ok: int = 0                     # served within their SLO
    # phase-split extras (zero for colocated routing)
    transfers: int = 0                  # cross-replica KV handoffs
    transfer_seconds: float = 0.0       # summed KV time on the fabric
    peak_kv_bytes: float = 0.0          # max KV resident awaiting decode
    transfers_avoided: int = 0          # session-affine reuse of resident
    #                                     decode state (no re-transfer)
    # deployment-elasticity extras (zero without a control timeline)
    rerouted: int = 0                   # in-flight requests re-routed off
    #                                     a failed group (recovered)
    dropped: int = 0                    # accepted requests lost because
    #                                     no eligible group remained
    # fault-injection extras (zero without a ``faults=`` plan)
    kv_retries: int = 0                 # failed KV chunk transfers that
    #                                     were retried with backoff
    kv_refills: int = 0                 # aborted handoffs re-prefilled
    #                                     on the decode group
    recovered: int = 0                  # crash victims restored from a
    #                                     checkpoint (vs replayed fresh)
    # events="agg" replaces the tuple log with this reduction (None in
    # "full" mode; both None under events=None)
    event_agg: Optional[EventAggregate] = None
    # paged-KV occupancy extras (zero without a ``kv=`` model)
    kv_hits: int = 0                    # follow-up turns that reused a
    #                                     resident session's KV prefix
    kv_hit_tokens: float = 0.0          # prompt tokens NOT re-prefilled
    kv_delayed: int = 0                 # admissions delayed by block
    #                                     pressure
    kv_evictions: int = 0               # resident sessions evicted (LRU)
    peak_kv_blocks: Tuple[int, ...] = ()    # per-group peak block use
    # contended-fabric extras (zero without a ``fabric=`` topology)
    fabric_wait_seconds: float = 0.0    # urgent KV queueing behind other
    #                                     traffic on shared channels
    fabric_bulk_bytes: float = 0.0      # completed bulk-class bytes
    fabric_bulk_seconds: float = 0.0    # channel seconds bulk occupied
    ckpt_shipped: int = 0               # checkpoint snapshots that fully
    #                                     crossed to the host store

    @property
    def throughput(self) -> float:
        return self.completed / max(self.makespan, 1e-12)

    @property
    def goodput(self) -> float:
        """Served-within-SLO requests per second (== throughput when no
        request carries an SLO)."""
        return self.slo_ok / max(self.makespan, 1e-12)

    @property
    def mean_latency(self) -> float:
        return sum(self.latencies) / max(len(self.latencies), 1)

    @property
    def mean_ttft(self) -> float:
        return sum(self.ttfts) / max(len(self.ttfts), 1)

    def p(self, q: float) -> float:
        xs = sorted(self.latencies)
        if not xs:
            return 0.0
        return xs[min(int(q * len(xs)), len(xs) - 1)]

    @property
    def cost_efficiency(self) -> float:
        """Requests per dollar ( throughput / $-rate ), paper Table III's
        cost-efficiency axis generalized to replica groups."""
        return self.throughput * 3600.0 / max(self.price_rate, 1e-12)


def _meets_slo(req: ClusterRequest, lat: float, ttft: float) -> bool:
    """Both SLO components must hold (absent components always hold)."""
    return ((req.slo is None or lat <= req.slo)
            and (req.slo_ttft is None or ttft <= req.slo_ttft))


def simulate_cluster(replicas: Sequence[ReplicaModel],
                     trace: Sequence[ClusterRequest],
                     route_fn) -> ClusterResult:
    """Composed cluster simulation under ``route_fn``.

    ``route_fn(req, replicas, now) -> replica index`` is consulted once
    per request at its arrival instant; a negative index sheds the
    request (admission control — it never touches a replica and counts
    toward neither throughput nor goodput).  Requests must be sorted by
    arrival.  Deterministic: identical (trace, plans, router) produce a
    bit-identical event log and makespan.

    Thin shim over :func:`simulate_deployment` (no phase splitting, no
    control timeline) — event logs are bit-identical to the historical
    standalone loop.
    """
    return simulate_deployment(replicas, trace, route_fn)


# --------------------------------------------------------------------- #
# Phase-split (prefill/decode) cluster simulation
# --------------------------------------------------------------------- #
#
# A request's prefill and decode phases can run on DIFFERENT replica
# groups with an explicit KV-transfer edge between them: prefill fills
# the KV/recurrent state on the prefill group, the state crosses the
# inter-replica fabric (``Interconnect``), and the decode group starts a
# decode_only session from the imported state.  The transfer is a
# first-class DES event (kind 2) and its time lands in TTFT — the first
# token cannot be streamed from the decode group before the state
# arrives.  This is the paper's headline heterogeneous scenario:
# prefill on the compute-rich device pool, decode on the cheap
# bandwidth-rich one.

#: event-log kind for a cross-replica KV transfer; the tuple is
#: (dst_replica, rid, KV_TRANSFER, src_replica, t_start, t_end).
KV_TRANSFER = 2

#: event-log kind for a bulk fabric transfer (checkpoint ship, session
#: migration); the tuple is (dst_group_or_-1_for_host, rid, FABRIC_BULK,
#: src_group, t_start, t_end).  Only emitted with ``fabric=`` set.
FABRIC_BULK = 3

#: pseudo destination group for the host-side checkpoint store
#: (mirrors serving.fabric.HOST; core cannot import serving).
FABRIC_HOST = -1


def _stream_kv(ic: Interconnect, nbytes: float, src: int, dst: int,
               pre_start: float, pre_fin: float, chunks: int,
               chan=None) -> Tuple[float, List[Tuple[float, float]], float]:
    """KV-arrival time of a (possibly chunked) prefill→decode handoff.

    Returns ``(kv_at, fabric_events, fabric_busy_seconds)``.

    ``chunks <= 1`` is the serial edge: one transfer starting at
    ``pre_fin`` (PR-3 semantics, bit-identical).  With ``chunks > 1``
    the prefill produces KV progressively — chunk c becomes available
    at the c/n point of the prefill span — and each chunk's transfer
    (``base_latency`` amortized per chunk) starts as soon as both the
    chunk and the fabric are ready, overlapping communication with the
    remaining prefill compute.  Only the tail that outlives the prefill
    lands in TTFT, so an optimal chunk size exists: large chunks defer
    too many bytes past ``pre_fin``, tiny chunks drown in per-transfer
    ``base_latency``.

    The sender knows every unit duration up front (simulated time), so
    it falls back to the serial schedule whenever chunking would lose —
    streamed ``kv_at`` is therefore NEVER later than the serial edge
    (property-tested).

    With ``chan`` (a fabric :class:`~repro.serving.fabric.ChannelState`)
    the transfer is *channel-queued*: bandwidth/latency come from the
    shared channel, no attempt starts before the channel's urgent head,
    and the chosen schedule is committed to the channel so later
    transfers (and bulk traffic) pay for it.  The never-later property
    then holds against the serial edge *on the same loaded channel*.
    ``chan=None`` keeps the historical point-to-point math bit-exact.
    """
    if chan is not None:
        return _stream_kv_chan(chan, nbytes, pre_start, pre_fin, chunks)
    serial_dur = ic.transfer_time(nbytes, src, dst)
    serial = (pre_fin + serial_dur, [(pre_fin, pre_fin + serial_dur)],
              serial_dur)
    span = pre_fin - pre_start
    if chunks <= 1 or nbytes <= 0.0 or src == dst or span <= 0.0:
        return serial
    per = ic.base_latency + (nbytes / chunks) / ic.bandwidth(src, dst)
    done = pre_start
    evs: List[Tuple[float, float]] = []
    for c in range(1, chunks + 1):
        ready = pre_start + span * c / chunks
        s = max(ready, done)
        done = s + per
        evs.append((s, done))
    if done <= serial[0]:
        return done, evs, per * chunks
    return serial


def _stream_kv_chan(chan, nbytes: float, pre_start: float, pre_fin: float,
                    chunks: int
                    ) -> Tuple[float, List[Tuple[float, float]], float]:
    """Channel-queued :func:`_stream_kv`: same chunk logic, but every
    attempt also waits for the shared channel's urgent head, and the
    winning schedule is committed so the contention is visible to every
    later transfer on the channel."""
    if nbytes <= 0.0:
        return pre_fin, [(pre_fin, pre_fin)], 0.0
    uf0 = chan.head()
    serial_dur = chan.duration(nbytes)
    serial_s = max(pre_fin, uf0)
    serial = (serial_s + serial_dur,
              [(serial_s, serial_s + serial_dur)], serial_dur)
    span = pre_fin - pre_start
    if chunks <= 1 or span <= 0.0:
        chan.commit_urgent(serial[1], pre_fin, nbytes)
        return serial
    per = chan.latency + (nbytes / chunks) / chan.bw
    done = max(pre_start, uf0)
    evs: List[Tuple[float, float]] = []
    for c in range(1, chunks + 1):
        ready = pre_start + span * c / chunks
        s = max(ready, done)
        done = s + per
        evs.append((s, done))
    if done <= serial[0]:
        chan.commit_urgent(evs, pre_start + span / chunks, nbytes)
        return done, evs, per * chunks
    chan.commit_urgent(serial[1], pre_fin, nbytes)
    return serial


def _stream_kv_flaky(ic: Interconnect, nbytes: float, src: int, dst: int,
                     pre_start: float, pre_fin: float, chunks: int, link,
                     chan=None) -> Tuple[Optional[float],
                                         List[Tuple[float, float]], float,
                                         int]:
    """Fault-injected variant of :func:`_stream_kv`.

    ``link`` (see serving/faults.FaultState.link) carries the per-link
    failure probability ``p``, a seeded ``rng``, and the retry policy
    (``max_retries``, ``backoff``, ``deadline``).  Each chunk transfer
    fails independently with probability ``p``; a failed attempt still
    occupies the fabric for the full chunk time (the bytes moved, the
    checksum did not) and is retried after exponential backoff.  When
    a chunk exhausts its retries, or a retry would start past the
    transfer deadline (``pre_fin + deadline``), the handoff ABORTS:
    ``kv_at`` comes back ``None`` and the caller re-prefills on the
    decode group.  Returns ``(kv_at, events, busy_seconds, retries)``.

    With zero failure draws the schedule — including the never-later
    serial fallback — is bit-identical to :func:`_stream_kv`, and a
    fault-free transfer never aborts regardless of the deadline.

    With ``chan`` the transfer is channel-queued exactly as in
    :func:`_stream_kv_chan`: attempts (including failed ones — the
    bytes moved, the checksum did not) wait for and occupy the shared
    channel, and whatever schedule results is committed to it.
    """
    if nbytes <= 0.0 or src == dst:
        kv_at, evs, busy = _stream_kv(ic, nbytes, src, dst, pre_start,
                                      pre_fin, chunks, chan)
        return kv_at, evs, busy, 0
    span = pre_fin - pre_start
    streamed = chunks > 1 and span > 0.0
    n = chunks if streamed else 1
    if chan is not None:
        per = chan.latency + (nbytes / n) / chan.bw
    elif streamed:
        per = ic.base_latency + (nbytes / n) / ic.bandwidth(src, dst)
    else:
        per = ic.transfer_time(nbytes, src, dst)
    deadline = pre_fin + link.deadline
    rng = link.rng
    done = pre_start if streamed else pre_fin
    uf0 = 0.0
    if chan is not None:
        uf0 = chan.head()
        done = max(done, uf0)
    r0 = pre_start + span / n if streamed else pre_fin

    def _commit(spans: List[Tuple[float, float]]) -> None:
        if chan is not None and spans:
            chan.commit_urgent(spans, r0, nbytes)

    evs: List[Tuple[float, float]] = []
    busy = 0.0
    retries = 0
    failed_any = False
    for c in range(1, n + 1):
        ready = pre_start + span * c / n if streamed else pre_fin
        s = max(ready, done)
        attempt = 0
        while True:
            if failed_any and s + per > deadline:
                _commit(evs)
                return None, evs, busy, retries
            end = s + per
            evs.append((s, end))
            busy += per
            if rng.random() >= link.p:
                done = end
                break
            failed_any = True
            retries += 1
            attempt += 1
            if attempt > link.max_retries:
                _commit(evs)
                return None, evs, busy, retries
            s = end + link.backoff * (2.0 ** (attempt - 1))
    if not failed_any and streamed:
        if chan is not None:
            serial_dur = chan.duration(nbytes)
            serial_s = max(pre_fin, uf0)
        else:
            serial_dur = ic.transfer_time(nbytes, src, dst)
            serial_s = pre_fin
        if done > serial_s + serial_dur:
            serial_evs = [(serial_s, serial_s + serial_dur)]
            if chan is not None:
                chan.commit_urgent(serial_evs, pre_fin, nbytes)
            return serial_s + serial_dur, serial_evs, serial_dur, 0
    _commit(evs)
    return done, evs, busy, retries


def simulate_cluster_pd(replicas: Sequence[ReplicaModel],
                        trace: Sequence[ClusterRequest],
                        route_fn,
                        interconnect: Optional[Interconnect] = None,
                        kv_chunks: int = 1) -> ClusterResult:
    """Cluster simulation where the router may split phases.

    ``route_fn(req, replicas, now)`` returns either a plain replica
    index (colocated; negative = shed) or a 3-tuple ``(prefill_idx,
    decode_idx, admit_at)`` — ``admit_at >= now`` is the rate-matched
    prefill admission time (see router.PDRouter).  Deterministic like
    :func:`simulate_cluster`.

    ``kv_chunks > 1`` enables OVERLAPPED KV streaming: the single
    kind-2 transfer edge is replaced by per-chunk transfer events that
    run concurrently with the remaining prefill units (see
    :func:`_stream_kv`), so only the transfer tail lands in TTFT.
    Routers exposing a ``transfers_avoided`` counter (PDRouter
    session affinity) have the per-run delta reported in the result.

    Thin shim over :func:`simulate_deployment` (no control timeline) —
    event logs are bit-identical to the historical standalone loop.
    """
    return simulate_deployment(replicas, trace, route_fn,
                               interconnect=interconnect,
                               kv_chunks=kv_chunks)


# --------------------------------------------------------------------- #
# Unified deployment simulation: routing + phase split + elasticity
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ControlEvent:
    """One entry of a deployment's elasticity timeline.

    ``kind``:
      * ``"up"``   — the group finishes warm-up at ``time`` and becomes
        routable (a group with a pending "up" starts ineligible),
      * ``"down"`` — graceful drain from ``time``: the router stops
        sending new requests there, resident work finishes normally,
      * ``"fail"`` — hard kill at ``time``: masked like "down", AND
        every in-flight request whose completion still depends on the
        group is re-routed across the survivors from ``time``,
      * ``"slow"`` — transient straggle: from ``time`` the group's
        stage units (and its service predictions, so routers observe
        the slowdown) are inflated by ``factor``; a later "slow" with
        ``factor=1.0`` ends the window.  Does not touch eligibility.

    An "up" whose group has an earlier "fail"/"down" in the SAME static
    timeline is a RECOVERY (crash-and-return): the group starts
    eligible and comes back at ``time``.  Only a group whose FIRST
    event is "up" starts masked (warm-up pending) — see
    :func:`validate_timeline`.
    """
    time: float
    kind: str                   # "up" | "down" | "fail" | "slow"
    group: int
    factor: float = 1.0         # service-time multiplier ("slow" only)

    def __post_init__(self):
        if self.kind not in ("up", "down", "fail", "slow"):
            raise ValueError(f"unknown control-event kind {self.kind!r}")
        if self.factor <= 0.0:
            raise ValueError(f"control-event factor must be > 0, "
                             f"got {self.factor!r}")


#: fail/down before up at the same instant: a group swapped in exactly
#: when another dies must not absorb the dead group's in-flight work
#: before its own warm-up event has fired.  "slow" applies after any
#: eligibility flip at the same instant.
_EVENT_ORDER = {"fail": 0, "down": 1, "up": 2, "slow": 3}


def validate_timeline(events: Sequence[ControlEvent], n_groups: int,
                      start_ineligible: Sequence[int] = ()) -> set:
    """Validate a STATIC control timeline; returns the groups that
    must start masked.

    Rejects contradictory timelines instead of silently replaying
    them: a "fail"/"down" for a group that is already down (duplicate
    fails, fail-after-down) and an "up" for a group that is already
    eligible both raise ``ValueError``.  A group whose FIRST
    eligibility event is "up" is warming up and starts masked; an "up"
    that FOLLOWS a "fail"/"down" is a recovery and must not mask the
    group from t=0 (the historical setup loop masked on ANY "up",
    which made crash-and-return timelines serve nothing before the
    crash).  "slow" events only have their group index checked.

    Controller-injected runtime events are not validated here — the
    controller reacts to live state the static timeline cannot see.
    """
    ordered = sorted(events, key=lambda e: (e.time, _EVENT_ORDER[e.kind],
                                            e.group))
    reserve = {int(g) for g in start_ineligible}
    state: Dict[int, bool] = {}
    start_masked: set = set()
    for e in ordered:
        if e.group < 0 or e.group >= n_groups:
            raise ValueError(f"control event {e} names group {e.group}; "
                             f"deployment has {n_groups}")
        if e.kind == "slow":
            continue
        if e.group not in state:
            if e.kind == "up" and e.group not in reserve:
                start_masked.add(e.group)
                state[e.group] = False
            else:
                # reserve groups already start masked; their
                # activation "up" needs no extra warm-up masking
                state[e.group] = e.group not in reserve
        if e.kind == "up":
            if state[e.group]:
                raise ValueError(
                    f"contradictory timeline: 'up' at t={e.time:g} for "
                    f"group {e.group}, which is already eligible")
            state[e.group] = True
        else:
            if not state[e.group]:
                raise ValueError(
                    f"contradictory timeline: {e.kind!r} at "
                    f"t={e.time:g} for group {e.group}, which is "
                    f"already down")
            state[e.group] = False
    return start_masked


@dataclasses.dataclass(frozen=True)
class ControlSignals:
    """Windowed cluster state handed to a deployment controller at each
    decision epoch of :func:`simulate_deployment`.

    Counters cover the epoch that just ended: ``arrivals`` fresh
    requests, ``shed`` of them rejected at admission, ``slo_miss`` of
    them admitted on a schedule that already misses an SLO component
    (the DES commits whole schedules at routing time, so the miss is
    known immediately).  Per-group vectors are indexed like the
    deployment's groups: ``backlog``/``queue_len`` are instantaneous at
    ``now``; ``util`` is the device-busy seconds *committed* during the
    epoch over the epoch's device-seconds, clamped to [0, 1] (committed
    work is the DES's analogue of measured occupancy); ``eligible`` is
    the routability mask.
    """
    now: float
    interval: float
    arrivals: int
    shed: int
    slo_miss: int
    backlog: Tuple[float, ...]
    queue_len: Tuple[int, ...]
    util: Tuple[float, ...]
    eligible: Tuple[bool, ...]
    # per-group KV-block utilization at ``now`` (empty without a
    # ``kv=`` occupancy model — the default keeps old callers intact)
    kv_util: Tuple[float, ...] = ()
    # per-group service-time accounting over the epoch: ``service_obs``
    # sums the service seconds the DES committed (including any straggle
    # inflation), ``service_model`` the same work priced by the group's
    # un-degraded profile.  Their ratio is the observable a straggle
    # detector thresholds on.  Empty tuples for old callers.
    service_obs: Tuple[float, ...] = ()
    service_model: Tuple[float, ...] = ()


# --------------------------------------------------------------------- #
# Paged-KV occupancy: the DES mirror of serving/kvpool.PagedKvCache
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class _KvGroup:
    """One group's block-pool state inside :class:`KvPoolModel`."""
    capacity: int
    free: int
    # (finish, seq, blocks, session, tokens) — sessions still decoding
    active: List[Tuple] = dataclasses.field(default_factory=list)
    # session -> [blocks, tokens, last_use]; insertion order == LRU
    resident: Dict = dataclasses.field(default_factory=dict)
    # rid -> (blocks, tokens) between admit() and release()
    pending: Dict[int, Tuple[int, int]] = \
        dataclasses.field(default_factory=dict)
    peak: int = 0


class KvPoolModel:
    """Per-group paged-KV occupancy for the DES.

    Each group owns ``pool_blocks`` blocks of ``block_tokens`` tokens.
    An admitted request holds ``ceil((prompt + output) / block_tokens)``
    blocks from admission to completion; a completed SESSION stays
    resident (cache retained) until block pressure evicts it LRU.
    Three observable effects feed the serving loop:

      * **prefix/session cache hits** — a follow-up turn routed to the
        group where its session is resident skips re-prefilling the
        cached prefix (``scale_prompt`` shrinks): the measured benefit
        side of decode-session affinity;
      * **delayed admission** — out of free blocks, a request waits for
        the earliest active finish (``kv_delayed`` counts these);
      * **memory-pressure signal** — per-group block utilization
        reaches routers (``kv_util_fn`` penalty) and controllers
        (:class:`ControlSignals.kv_util`).

    ``base_prompt``/``base_output`` convert request scales back to
    token counts (the inverse of ``HeteroCluster.to_cluster_request``).
    Deterministic, and strictly opt-in: ``simulate_deployment(kv=None)``
    is bit-identical to not having the model at all.
    """

    def __init__(self, block_tokens: int = 64, pool_blocks: int = 1024,
                 *, base_prompt: int = 1024, base_output: int = 256):
        assert block_tokens >= 1 and pool_blocks >= 1
        assert base_prompt >= 1 and base_output >= 1
        self.block_tokens = block_tokens
        self.pool_blocks = pool_blocks
        self.base_prompt = base_prompt
        self.base_output = base_output
        self._g: List[_KvGroup] = []
        self._seq = 0
        self.hits = 0
        self.hit_tokens = 0.0
        self.delayed = 0
        self.evictions = 0

    def bind(self, n_groups: int) -> "KvPoolModel":
        """Fresh per-group state for one simulation run (idempotent —
        a model instance can be reused across runs)."""
        self._g = [_KvGroup(self.pool_blocks, self.pool_blocks)
                   for _ in range(n_groups)]
        self._seq = 0
        self.hits = 0
        self.hit_tokens = 0.0
        self.delayed = 0
        self.evictions = 0
        return self

    # -------------------------------------------------------------- #
    def prompt_tokens(self, req: ClusterRequest) -> int:
        return max(1, round(req.scale_prompt * self.base_prompt))

    def _blocks(self, tokens: int) -> int:
        return max(1, -(-tokens // self.block_tokens))

    def _expire(self, st: _KvGroup, t: float) -> None:
        """Finished actives become resident (cache retained); a
        sessionless request's blocks free immediately."""
        while st.active and st.active[0][0] <= t:
            fin, _, blocks, session, tokens = heapq.heappop(st.active)
            if session is None:
                st.free += blocks
                continue
            old = st.resident.pop(session, None)
            if old is not None:
                st.free += old[0]
            st.resident[session] = [blocks, tokens, fin]

    # -------------------------------------------------------------- #
    def cached(self, g: int, session, t: float) -> int:
        """Tokens of ``session``'s KV resident on group ``g`` at ``t``
        (0 when absent).  Touches the entry's LRU position."""
        st = self._g[g]
        self._expire(st, t)
        ent = st.resident.pop(session, None)
        if ent is None:
            return 0
        ent[2] = t
        st.resident[session] = ent      # reinsert == move to MRU end
        return ent[1]

    def admit(self, g: int, req: ClusterRequest, at: float) -> float:
        """Reserve blocks for ``req`` on group ``g``; returns the
        admission time (``>= at`` — later when the request had to wait
        for blocks).  Pressure order: evict idle resident sessions
        LRU, then wait for the earliest active finish."""
        st = self._g[g]
        t = at
        self._expire(st, t)
        p = self.prompt_tokens(req)
        o = max(1, round(req.scale_output * self.base_output))
        need = min(self._blocks(p + o), st.capacity)
        if req.session is not None:
            # a resident prior turn re-admits: its blocks roll into
            # the new (accumulated-context) reservation
            old = st.resident.pop(req.session, None)
            if old is not None:
                st.free += old[0]
        delayed = False
        while st.free < need:
            if st.resident:
                lru = next(iter(st.resident))
                st.free += st.resident.pop(lru)[0]
                self.evictions += 1
                continue
            fin, _, blocks, _, _ = heapq.heappop(st.active)
            st.free += blocks
            if fin > t:
                t = fin
                delayed = True
        st.free -= need
        st.pending[req.rid] = (need, p + o)
        st.peak = max(st.peak, st.capacity - st.free)
        if delayed:
            self.delayed += 1
        return t

    def release(self, g: int, req: ClusterRequest,
                finish: float) -> None:
        """Hand the request's blocks to the finish heap: they free (or
        turn resident) once the decode completes at ``finish``."""
        st = self._g[g]
        ent = st.pending.pop(req.rid, None)
        if ent is None:
            return
        self._seq += 1
        heapq.heappush(st.active,
                       (finish, self._seq, ent[0], req.session, ent[1]))

    def clear(self, g: int) -> None:
        """Hard reset one group (its pool died with a failed group)."""
        self._g[g] = _KvGroup(self.pool_blocks, self.pool_blocks)

    # -------------------------------------------------------------- #
    def util_at(self, g: int, t: float) -> float:
        st = self._g[g]
        self._expire(st, t)
        return (st.capacity - st.free) / st.capacity

    def util_vec(self, t: float) -> Tuple[float, ...]:
        return tuple(self.util_at(g, t) for g in range(len(self._g)))

    def peaks(self) -> Tuple[int, ...]:
        return tuple(st.peak for st in self._g)


def simulate_deployment(replicas: Sequence[ReplicaModel],
                        trace: Sequence[ClusterRequest],
                        route_fn,
                        interconnect: Optional[Interconnect] = None,
                        kv_chunks: int = 1,
                        timeline: Sequence[ControlEvent] = (),
                        controller=None,
                        start_ineligible: Sequence[int] = (),
                        events: Optional[str] = "full",
                        kv: Optional[KvPoolModel] = None,
                        faults=None,
                        fabric=None) -> ClusterResult:
    """One DES entry point behind every serving surface.

    Subsumes :func:`simulate_cluster` (colocated routing) and
    :func:`simulate_cluster_pd` (phase-split routing with a KV-transfer
    edge): ``route_fn`` may return a plain index, ``-1``/``None``
    (shed), or a ``(prefill_idx, decode_idx, admit_at)`` tuple.  With
    an empty ``timeline`` the event log is bit-identical to the
    historical per-entry-point loops.

    ``timeline`` adds deployment elasticity (see :class:`ControlEvent`):
    groups can warm up, drain, or fail mid-trace.  Masking is the same
    mechanism for all three — the event flips ``ReplicaModel.eligible``
    and every router skips ineligible groups.  On a failure, in-flight
    requests whose completion still depended on the dead group (decode
    resident there, or KV not yet landed from a dead prefill source)
    are re-submitted through ``route_fn`` at the failure instant; their
    latency/TTFT then count from the ORIGINAL arrival (the client's
    view of a retried request).  Nothing is rolled back from any
    resource timeline: work a victim already performed is wasted (as
    on real machines), and a victim's PRE-BOOKED future work on
    surviving groups (e.g. the decode interval reserved for KV that a
    dead prefill source will never deliver) stays reserved too — the
    DES commits whole schedules at routing time and does not model
    cancellation, so survivors look conservatively busier during a
    failure than a cancelling runtime would.  A victim with no
    eligible group left to re-route to is counted in ``dropped``
    (accepted, then lost); a FRESH arrival the router rejects — for
    admission control or because no eligible group remains — counts in
    ``shed`` as always (it was never accepted).

    ``controller`` closes the loop: an object exposing ``interval``
    (decision-epoch seconds), ``begin(t0)``, ``decide(signals) ->
    iterable[ControlEvent]`` and ``finish(t_end)`` (see
    ``serving/controller.AutoscalePolicy``).  Every ``interval``
    seconds of simulated time it receives a :class:`ControlSignals`
    snapshot of the epoch just ended and may inject new control events
    (at or after ``now``) into the live timeline — the same masking
    machinery static timelines use.  ``start_ineligible`` lists groups
    that begin masked with no pending "up" event (a controller's
    parked reserve pool).

    ``events`` selects the recording mode: ``"full"`` (default) keeps
    the per-unit tuple log, ``"agg"`` keeps only the
    :class:`EventAggregate` reduction (the memory that matters at 1M
    requests), ``None`` records nothing.  The schedule itself is
    identical in every mode — recording is strictly observational.

    ``faults`` is a BOUND fault state (``serving.faults.FaultPlan
    .bind()``; crash/straggle events arrive via ``timeline``).  Three
    hooks, each strictly opt-in so ``faults=None`` runs stay
    bit-identical: per-link flaky KV transfers route through
    :func:`_stream_kv_flaky` (seeded retries, abort → re-prefill on
    the decode group, counted in ``kv_retries``/``kv_refills``);
    ``faults.recovery`` replays crash victims from their last periodic
    checkpoint (decode work before the checkpoint is NOT re-run, a
    host-restore delay is charged, and a victim with no eligible group
    is PARKED in the host store and replayed at the next "up" instead
    of dropping — still-parked requests at end of trace count as
    ``dropped``); ``faults.health`` observes transfer errors and
    eligibility flips (circuit breakers for health-aware routers).

    ``fabric`` (a ``serving.fabric.Topology``) replaces the
    point-to-point ``interconnect`` math for cross-group movement: KV
    handoffs become channel-queued urgent traffic on shared
    island/crossing channels, checkpoint ships (with
    ``faults.recovery``) and session migrations become preemptible
    bulk traffic on the SAME channels (``FABRIC_BULK`` events), and
    crash-replay progress counts checkpoints by their actual channel
    completion time instead of the unloaded periodic formula.
    Strictly opt-in: ``fabric=None`` runs are bit-identical to
    pre-fabric builds.

    Deterministic: identical (trace, plans, router, timeline,
    controller config, fault plan seed, fabric topology) produce a
    bit-identical event log.
    """
    if events not in ("full", "agg", None):
        raise ValueError(f"events must be 'full', 'agg' or None, "
                         f"got {events!r}")
    ic = interconnect or Interconnect()
    # Pending control events live in a heap so a controller can inject
    # events mid-run; the (time, kind-order, group, seq) key reproduces
    # the old sorted-list order exactly when nothing is injected.
    pend: List[Tuple[float, int, int, int, ControlEvent]] = []
    eseq = 0

    def push_event(e: ControlEvent) -> None:
        nonlocal eseq
        if e.group < 0 or e.group >= len(replicas):
            raise ValueError(f"control event {e} names group {e.group}; "
                             f"deployment has {len(replicas)}")
        heapq.heappush(pend, (e.time, _EVENT_ORDER[e.kind], e.group,
                              eseq, e))
        eseq += 1

    # Contradictory static timelines are rejected up front; only
    # groups whose FIRST event is "up" (warm-up pending) start masked,
    # so crash-and-recover timelines serve normally before the crash.
    start_masked = validate_timeline(timeline, len(replicas),
                                     start_ineligible)
    for e in sorted(timeline,
                    key=lambda e: (e.time, _EVENT_ORDER[e.kind], e.group)):
        push_event(e)
    for g in start_masked:
        replicas[g].eligible = False
    for g in start_ineligible:
        replicas[int(g)].eligible = False
    # Per-request mutable record, indexed by trace position.  "served"
    # records carry the request's CURRENT placement so a later failure
    # can find and re-route its victims.
    records: List[Optional[Dict]] = [None] * len(trace)
    ev_log: Optional[List[Tuple]] = [] if events == "full" else None
    agg: Optional[EventAggregate] = (EventAggregate()
                                     if events == "agg" else None)
    # queue_len is only ever probed by a controller epoch; without one
    # the per-request finish-heap push is pure churn
    track = controller is not None
    for rep in replicas:
        rep.track_inflight = track
    kv_resident: List[Tuple[float, float, float]] = []
    counters = {"shed": 0, "dropped": 0, "rerouted": 0,
                "transfers": 0, "transfer_seconds": 0.0,
                "kv_retries": 0, "kv_refills": 0, "recovered": 0}
    fstate = faults
    recovery = getattr(fstate, "recovery", None)
    health = getattr(fstate, "health", None)
    # (trace index, request to replay, ttft to preserve) of crash
    # victims waiting in the host-side checkpoint store for capacity
    parked: List[Tuple[int, ClusterRequest, Optional[float]]] = []
    avoided0 = int(getattr(route_fn, "transfers_avoided", 0))
    kvm = kv.bind(len(replicas)) if kv is not None else None
    # routers that look can see each group's block pressure; the
    # attribute is absent (not 0.0) when no kv model runs — and is
    # scrubbed on reuse — so kv-unaware runs stay bit-identical
    for gi, rep in enumerate(replicas):
        if kvm is not None:
            rep.kv_util_fn = (lambda t, g=gi: kvm.util_at(g, t))
        elif hasattr(rep, "kv_util_fn"):
            del rep.kv_util_fn
    # Contended fabric (strictly opt-in): bind the topology, point its
    # bulk-slice sink at whichever event record this run keeps, and let
    # a fabric-aware router charge queued transfer tails.
    fab = fabric.bind(len(replicas)) if fabric is not None else None
    if fab is not None:
        if ev_log is not None:
            def _bulk_sink(src, dst, rid, t0, t1,
                           _log=ev_log):
                _log.append((dst, rid, FABRIC_BULK, src, t0, t1))
        elif agg is not None:
            def _bulk_sink(src, dst, rid, t0, t1, _agg=agg):
                _agg.add(dst, FABRIC_BULK, src, t0, t1)
        else:
            def _bulk_sink(src, dst, rid, t0, t1):
                return None
        fab.sink = _bulk_sink
        if hasattr(route_fn, "bind_fabric"):
            route_fn.bind_fabric(fab)
    # Monotone tag source for bulk transfers (a crash victim's replay
    # must not collide with its first attempt's checkpoint tags).
    ship_seq = [0]

    def ship(i: int, g: int, d0: float, d1: float, kvb: float):
        """Enqueue this request's periodic checkpoint snapshots as
        bulk fabric traffic g -> host; returns the (group, seq, count)
        record crash recovery later counts completions against."""
        if fab is None or recovery is None:
            return None
        if d1 <= d0 or kvb <= 0.0:
            return None
        n_ship = int((d1 - d0) / recovery.interval)
        if n_ship <= 0:
            return None
        seq = ship_seq[0]
        ship_seq[0] += 1
        for k in range(1, n_ship + 1):
            fab.enqueue_bulk(g, FABRIC_HOST, trace[i].rid, kvb,
                             d0 + k * recovery.interval,
                             ("ckpt", seq, k))
        return (g, seq, n_ship)

    # Per-group service-seconds committed this control epoch: observed
    # (straggle-inflated) vs the un-degraded profile's price for the
    # same work.  Only maintained under a controller.
    svc_obs = [0.0] * len(replicas)
    svc_model = [0.0] * len(replicas)

    def note_service(rep: ReplicaModel, obs: float) -> None:
        svc_obs[rep.idx] += obs
        svc_model[rep.idx] += obs / rep.slow if rep.slow != 1.0 else obs

    def dispatch(i: int, req: ClusterRequest, now: float,
                 arrival0: float, fresh: bool) -> None:
        decision = route_fn(req, replicas, now)
        if not isinstance(decision, tuple):
            if decision is None or decision < 0:
                records[i] = {"served": False}
                counters["shed" if fresh else "dropped"] += 1
                return
            p_idx = d_idx = decision
            admit_at = req.arrival
        else:
            p_idx, d_idx, admit_at = decision
            admit_at = max(admit_at, req.arrival)
            if fab is not None:
                # A fabric-aware router that broke session affinity
                # flags the abandoned home so the resident state's
                # migration rides the fabric as bulk traffic.
                mig = getattr(route_fn, "pending_migration", None)
                if mig is not None:
                    route_fn.pending_migration = None
                    if mig != d_idx and req.kv_bytes > 0.0:
                        mseq = ship_seq[0]
                        ship_seq[0] += 1
                        fab.enqueue_bulk(mig, d_idx, req.rid,
                                         req.kv_bytes, now,
                                         ("mig", mseq))
        if kvm is not None:
            if req.session is not None and p_idx == d_idx:
                # follow-up turn landing on its resident group: the
                # cached prefix is not re-prefilled (session affinity's
                # measured benefit)
                got = kvm.cached(d_idx, req.session, admit_at)
                if got > 0:
                    p_tok = kvm.prompt_tokens(req)
                    eff = max(p_tok - got, 1)
                    if eff < p_tok:
                        kvm.hits += 1
                        kvm.hit_tokens += float(p_tok - eff)
                        req = dataclasses.replace(
                            req, scale_prompt=eff / kvm.base_prompt)
            # blocks live on the decode group from admission to finish;
            # under pressure the admission itself waits
            admit_at = kvm.admit(d_idx, req, admit_at)
        kv_i = None
        if p_idx == d_idx:
            rep = replicas[p_idx]
            finish, first_tok, _ = rep._run_units(req, ev_log, "both",
                                                  admit_at, agg)
            ttft_abs, kv_at = first_tok, None
            if track:
                note_service(rep, rep.predicted_service(req))
            if rep.monitor is not None:
                rep.monitor.record_request(
                    finish, finish - req.arrival,
                    rep.predicted_service(req))
                rep.maybe_switch(req.arrival)
        else:
            pre, dec = replicas[p_idx], replicas[d_idx]
            pre_fin, _, pre_start = pre._run_units(req, ev_log,
                                                   "prefill", admit_at,
                                                   agg)
            if track:
                note_service(pre,
                             pre.predicted_phase_service(req, "prefill"))
            link = fstate.link(p_idx, d_idx) if fstate is not None \
                else None
            chan = fab.channel(p_idx, d_idx) if fab is not None else None
            if link is None:
                kv_at, xfer_evs, busy = _stream_kv(
                    ic, req.kv_bytes, p_idx, d_idx, pre_start, pre_fin,
                    kv_chunks, chan)
            else:
                kv_at, xfer_evs, busy, nretry = _stream_kv_flaky(
                    ic, req.kv_bytes, p_idx, d_idx, pre_start, pre_fin,
                    kv_chunks, link, chan)
                counters["kv_retries"] += nretry
                if health is not None:
                    for _ in range(nretry):
                        health.record_error(p_idx, pre_fin)
                    if kv_at is not None:
                        health.record_ok(p_idx, pre_fin)
            for (x0, x1) in xfer_evs:
                if ev_log is not None:
                    ev_log.append((d_idx, req.rid, KV_TRANSFER, p_idx,
                                   x0, x1))
                elif agg is not None:
                    agg.add(d_idx, KV_TRANSFER, p_idx, x0, x1)
            counters["transfers"] += 1
            counters["transfer_seconds"] += busy
            if kv_at is None:
                # handoff aborted (retries exhausted / deadline blown):
                # the decode group re-prefills locally from the prompt.
                # The prefill group's work and the attempted transfers
                # are wasted, nothing became resident in flight, and a
                # later prefill-group death cannot hurt this request.
                counters["kv_refills"] += 1
                t_abort = xfer_evs[-1][1] if xfer_evs else pre_fin
                finish, first_tok, _ = dec._run_units(req, ev_log,
                                                      "both", t_abort,
                                                      agg)
                ttft_abs = first_tok
                if track:
                    note_service(dec, dec.predicted_service(req))
                if kvm is not None:
                    kvm.release(d_idx, req, finish)
                records[i] = {"served": True, "p": p_idx, "d": d_idx,
                              "finish": finish, "kv_at": None,
                              "kv_i": None, "d0": first_tok,
                              "lat": finish - arrival0,
                              "ttft": ttft_abs - arrival0,
                              "ship": ship(i, d_idx, first_tok, finish,
                                           req.kv_bytes)}
                return
            finish, _, _ = dec._run_units(req, ev_log, "decode", kv_at,
                                          agg)
            if track:
                note_service(dec,
                             dec.predicted_phase_service(req, "decode"))
            # first token streams from the decode group once the state
            # lands there — transfer time is part of TTFT
            ttft_abs = kv_at
            kv_i = len(kv_resident)
            kv_resident.append((kv_at, finish, req.kv_bytes))
            # each pool's monitor OBSERVES the queueing its own phase
            # caused (measured from when the work became available),
            # but split-routed replicas do NOT adopt policy flips: both
            # stored plans optimize whole-request objectives, so
            # flipping a pool between them mid-split degrades both
            # phases (measured in benchmarks/pd_split.py) — a pool's
            # plan choice is the router's role assignment.  Phase-
            # specific plans would make adaptation meaningful here;
            # until then the monitor's ratio history/would-be switches
            # stay visible without perturbing the schedule.
            if pre.monitor is not None:
                pre.monitor.record_request(
                    pre_fin, pre_fin - admit_at,
                    pre.predicted_phase_service(req, "prefill"))
            if dec.monitor is not None:
                dec.monitor.record_request(
                    finish, finish - kv_at,
                    dec.predicted_phase_service(req, "decode"))
        if kvm is not None:
            kvm.release(d_idx, req, finish)
        d0_anchor = ttft_abs if kv_at is None else kv_at
        records[i] = {"served": True, "p": p_idx, "d": d_idx,
                      "finish": finish, "kv_at": kv_at,
                      "kv_i": kv_i,
                      # decode-start anchor checkpoint recovery
                      # measures replay progress from
                      "d0": d0_anchor,
                      "lat": finish - arrival0,
                      "ttft": ttft_abs - arrival0,
                      "ship": ship(i, d_idx, d0_anchor, finish,
                                   req.kv_bytes)}

    def redispatch(i: int, req: ClusterRequest, arrival0: float,
                   keep_ttft: Optional[float]) -> None:
        """Re-submit a crash victim.  With recovery enabled a victim
        the router cannot place is PARKED (its checkpoint lives in the
        host store) and replayed at the next "up" event instead of
        dropping; ``keep_ttft`` preserves the client-visible TTFT of a
        checkpoint-restored session (its first token streamed long
        ago)."""
        dispatch(i, req, req.arrival, arrival0, fresh=False)
        rec = records[i]
        if not rec["served"]:
            if recovery is not None:
                counters["dropped"] -= 1
                parked.append((i, req, keep_ttft))
            return
        if keep_ttft is not None:
            rec["ttft"] = keep_ttft

    def apply_events(upto: float) -> None:
        while pend and pend[0][0] <= upto:
            e = heapq.heappop(pend)[-1]
            rep = replicas[e.group]
            if e.kind == "slow":
                rep.slow = e.factor
                continue
            if e.kind == "up":
                rep.eligible = True
                if health is not None:
                    health.reset(e.group, e.time)
                if parked:
                    waiting, parked[:] = list(parked), []
                    for (i, preq, keep_ttft) in waiting:
                        redispatch(i, dataclasses.replace(
                            preq, arrival=e.time),
                            trace[i].arrival, keep_ttft)
                continue
            rep.eligible = False
            if e.kind != "fail":
                continue            # graceful drain: residents finish
            if health is not None:
                health.trip(e.group, e.time)
            if kvm is not None:
                kvm.clear(e.group)  # the block pool died with the group
            for i, rec in enumerate(records):
                if rec is None or not rec["served"]:
                    continue
                hit = ((rec["d"] == e.group and rec["finish"] > e.time)
                       or (rec["p"] == e.group
                           and rec["kv_at"] is not None
                           and rec["kv_at"] > e.time))
                if not hit:
                    continue
                # the completion credited at first submission never
                # materialized on the dead group
                replicas[rec["d"]].completed -= 1
                if rec["kv_i"] is not None:
                    # the victim's resident-KV interval ends at the
                    # failure (decode group dead: state vanished with
                    # it; prefill source dead mid-transfer: the state
                    # never landed) — without this the re-routed
                    # transfer would double-count in peak_kv_bytes
                    a0, a1, w = kv_resident[rec["kv_i"]]
                    t1 = min(a1, e.time)
                    kv_resident[rec["kv_i"]] = \
                        (a0, t1, w) if a0 < t1 else (a0, a0, 0.0)
                counters["rerouted"] += 1
                if recovery is None:
                    dispatch(i, dataclasses.replace(trace[i],
                                                    arrival=e.time),
                             e.time, trace[i].arrival, fresh=False)
                    continue
                # checkpoint replay-cost model: decode work up to the
                # last periodic checkpoint (every `interval` seconds
                # from the decode start) is NOT re-run; the survivor
                # charges a host-restore delay and replays only the
                # post-checkpoint suffix.  A victim that never started
                # decoding (or died inside its first interval) has no
                # checkpoint and replays from scratch.
                vic = dataclasses.replace(trace[i], arrival=e.time)
                keep_ttft = None
                d0, d1 = rec["d0"], rec["finish"]
                if rec["d"] == e.group and e.time > d0 and d1 > d0:
                    if fab is not None:
                        # checkpoints only count once their snapshot
                        # fully crossed the (possibly contended)
                        # fabric to the host store
                        k = fab.ships_done(rec.get("ship"), e.time)
                    else:
                        k = math.floor((e.time - d0) / recovery.interval)
                    frac = min(k * recovery.interval / (d1 - d0), 1.0)
                    if frac > 0.0:
                        restore = (recovery.base_latency
                                   + trace[i].kv_bytes
                                   / recovery.restore_bw)
                        vic = dataclasses.replace(
                            trace[i], arrival=e.time + restore,
                            scale_prompt=0.0,
                            scale_output=(trace[i].scale_output
                                          * (1.0 - frac)))
                        keep_ttft = rec["ttft"]
                        counters["recovered"] += 1
                redispatch(i, vic, trace[i].arrival, keep_ttft)
            if fab is not None:
                # the dead group's memory is gone: whatever it had not
                # finished shipping will never ship (slices already on
                # the wire stay — that bandwidth was genuinely spent)
                fab.cancel_src(e.group, e.time)

    # ------------------------------------------------------------- #
    # closed-loop control: every `interval` seconds of simulated time
    # the controller sees the epoch's signals and may inject events
    if controller is not None:
        ctl_dt = float(getattr(controller, "interval", 0.0))
        if ctl_dt <= 0.0:
            raise ValueError("controller.interval must be > 0")
        ctl_t0 = min((r.arrival for r in trace), default=0.0)
        next_epoch = ctl_t0 + ctl_dt
        busy_prev = [sum(r.dev_busy) for r in replicas]
        ctl_counts = {"arrivals": 0, "shed": 0, "miss": 0}
        controller.begin(ctl_t0)

    def fire_epoch(te: float) -> None:
        apply_events(te)
        util = []
        for gi, rep in enumerate(replicas):
            busy = sum(rep.dev_busy)
            cap = ctl_dt * rep.num_devices
            util.append(min(1.0, max(0.0, (busy - busy_prev[gi]) / cap)))
            busy_prev[gi] = busy
        sig = ControlSignals(
            now=te, interval=ctl_dt,
            arrivals=ctl_counts["arrivals"], shed=ctl_counts["shed"],
            slo_miss=ctl_counts["miss"],
            backlog=tuple(r.backlog(te) for r in replicas),
            queue_len=tuple(r.queue_len(te) for r in replicas),
            util=tuple(util),
            eligible=tuple(r.eligible for r in replicas),
            kv_util=(kvm.util_vec(te) if kvm is not None else ()),
            service_obs=tuple(svc_obs),
            service_model=tuple(svc_model))
        ctl_counts.update(arrivals=0, shed=0, miss=0)
        for gi in range(len(replicas)):
            svc_obs[gi] = 0.0
            svc_model[gi] = 0.0
        for ev in (controller.decide(sig) or ()):
            if ev.time < te:
                raise ValueError(f"controller event {ev} is in the "
                                 f"past (now={te})")
            push_event(ev)

    for i, req in enumerate(trace):
        if controller is not None:
            while next_epoch <= req.arrival:
                fire_epoch(next_epoch)
                next_epoch += ctl_dt
        apply_events(req.arrival)
        if fab is not None:
            # Every future urgent booking has ready >= this arrival
            # (dispatch order), so the arrival is a safe watermark to
            # drain pending bulk below.
            fab.materialize(req.arrival)
        dispatch(i, req, req.arrival, req.arrival, fresh=True)
        if controller is not None:
            ctl_counts["arrivals"] += 1
            rec = records[i]
            if not rec["served"]:
                ctl_counts["shed"] += 1
            elif not _meets_slo(req, rec["lat"], rec["ttft"]):
                ctl_counts["miss"] += 1
    apply_events(math.inf)          # events after the last arrival
    if fab is not None:
        fab.flush()                 # drain every remaining bulk ship
    # victims still parked when the trace ends never found capacity
    counters["dropped"] += len(parked)

    latencies: List[float] = []
    ttfts: List[float] = []
    assignments: List[int] = []
    max_finish = 0.0
    slo_ok = 0
    for req, rec in zip(trace, records):
        if not rec["served"]:
            assignments.append(-1)
            continue
        assignments.append(rec["d"])
        latencies.append(rec["lat"])
        ttfts.append(rec["ttft"])
        if _meets_slo(req, rec["lat"], rec["ttft"]):
            slo_ok += 1
        max_finish = max(max_finish, rec["finish"])
    t0 = min((r.arrival for r in trace), default=0.0)
    if controller is not None:
        controller.finish(max(max_finish, t0))
    return ClusterResult(
        makespan=max_finish - t0 if trace else 0.0,
        completed=len(latencies),
        latencies=latencies,
        assignments=assignments,
        per_replica_completed=[r.completed for r in replicas],
        per_replica_busy=[sum(r.dev_busy) for r in replicas],
        switches=sum(r.switches for r in replicas),
        events=ev_log if ev_log is not None else [],
        event_agg=agg,
        price_rate=sum(r.price for r in replicas),
        ttfts=ttfts, shed=counters["shed"], slo_ok=slo_ok,
        transfers=counters["transfers"],
        transfer_seconds=counters["transfer_seconds"],
        peak_kv_bytes=_peak_concurrent(kv_resident),
        transfers_avoided=int(getattr(route_fn, "transfers_avoided", 0))
        - avoided0,
        rerouted=counters["rerouted"], dropped=counters["dropped"],
        kv_retries=counters["kv_retries"],
        kv_refills=counters["kv_refills"],
        recovered=counters["recovered"],
        kv_hits=kvm.hits if kvm is not None else 0,
        kv_hit_tokens=kvm.hit_tokens if kvm is not None else 0.0,
        kv_delayed=kvm.delayed if kvm is not None else 0,
        kv_evictions=kvm.evictions if kvm is not None else 0,
        peak_kv_blocks=kvm.peaks() if kvm is not None else (),
        fabric_wait_seconds=(fab.stats()["wait_seconds"]
                             if fab is not None else 0.0),
        fabric_bulk_bytes=(fab.stats()["bulk_bytes"]
                           if fab is not None else 0.0),
        fabric_bulk_seconds=(fab.stats()["bulk_seconds"]
                             if fab is not None else 0.0),
        ckpt_shipped=fab.ckpt_completed() if fab is not None else 0)


def _peak_concurrent(intervals: Sequence[Tuple[float, float, float]]
                     ) -> float:
    """Max summed weight over overlapping [t0, t1) intervals."""
    deltas: List[Tuple[float, float]] = []
    for t0, t1, w in intervals:
        deltas.append((t0, w))
        deltas.append((t1, -w))
    peak = cur = 0.0
    for _, dw in sorted(deltas):
        cur += dw
        peak = max(peak, cur)
    return peak
