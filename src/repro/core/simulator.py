"""Discrete-event simulator of disaggregated pipelined execution.

The container has no heterogeneous hardware, so the paper's performance
experiments (offline throughput, online latency, pipeline ablation,
bandwidth robustness, monitor sensitivity) are reproduced on a
discrete-event model driven by the *same* cost model the planner uses:

  * one compute server per device (stages serialize on it),
  * one ingress-link server per device (cut-edge transfers serialize on
    it, the paper's receiver-side M_g),
  * compute and communication on a device overlap (separate servers) —
    the premise of the paper's pipelined execution model,
  * scheduling: "priority" (oldest request first — the paper's
    priority-aware streams) or "fifo" (naive multi-streaming),
  * pipelining off = one request admitted at a time.

Simulated time is deterministic; no wall clocks are read.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.graph import KernelGraph
from repro.core.planner import Plan
from repro.core.monitor import MonitorConfig, OnlineMonitor


@dataclasses.dataclass
class StageTask:
    """Per-request instance of a plan stage."""
    stage_idx: int
    device: int
    compute: float
    ingress: float          # serialized transfer time on the ingress link


def stage_tasks(graph: KernelGraph, plan: Plan, devices,
                bw_override: Optional[float] = None) -> List[StageTask]:
    tasks = []
    for st in plan.stages:
        nset = set(st.node_ids)
        ingress = 0.0
        for (i, j), b in graph.edges.items():
            if j in nset and plan.labels[i] != st.device:
                rep = max(graph.nodes[i].repeat, graph.nodes[j].repeat)
                ingress += devices[plan.labels[i]].transfer_time(
                    b, devices[st.device], bw_override, repeat=rep)
        tasks.append(StageTask(st.idx, st.device, st.compute_time, ingress))
    # recompute stage compute under (possibly) overridden devices
    for t, st in zip(tasks, plan.stages):
        t.compute = sum(devices[st.device].kernel_time(graph.nodes[k])
                        for k in st.node_ids)
    return tasks


@dataclasses.dataclass
class SimResult:
    makespan: float
    completed: int
    latencies: List[float]
    device_busy: List[float]        # compute-busy seconds per device
    link_busy: List[float]          # ingress-busy seconds per device
    switches: int = 0

    @property
    def throughput(self) -> float:
        return self.completed / max(self.makespan, 1e-12)

    @property
    def mean_latency(self) -> float:
        return sum(self.latencies) / max(len(self.latencies), 1)

    def p(self, q: float) -> float:
        xs = sorted(self.latencies)
        if not xs:
            return 0.0
        return xs[min(int(q * len(xs)), len(xs) - 1)]

    def busy_fraction(self, dev: int) -> float:
        return self.device_busy[dev] / max(self.makespan, 1e-12)


# --------------------------------------------------------------------- #
class _DES:
    """Core event loop shared by offline and online modes."""

    def __init__(self, tasks: List[StageTask], num_devices: int,
                 scheduling: str = "priority", pipelined: bool = True,
                 max_inflight: int = 16):
        self.tasks = tasks
        self.nG = num_devices
        self.scheduling = scheduling
        self.pipelined = pipelined
        self.max_inflight = max_inflight if pipelined else 1

        self.dev_free = [0.0] * num_devices
        self.link_free = [0.0] * num_devices
        self.dev_busy = [0.0] * num_devices
        self.link_busy = [0.0] * num_devices

    def run(self, arrivals: List[float],
            iters_per_request: int = 1,
            stall_windows: Optional[List[Tuple[float, float]]] = None
            ) -> SimResult:
        """arrivals[r] = submit time of request r (must be sorted).

        Each stage is two independently-scheduled units — a transfer on
        the receiver's ingress link, then compute on the device — so the
        link and device queues pack independently (committing both at
        once reserves idle gaps and under-utilizes both)."""
        n = len(arrivals)
        # unit list: (kind 0=link/1=dev, device, duration)
        units: List[Tuple[int, int, float]] = []
        for t in self.tasks:
            if t.ingress > 0:
                units.append((0, t.device, t.ingress))
            units.append((1, t.device, t.compute))
        total_units = len(units) * iters_per_request
        cursor = [0] * n
        ready_at = [a for a in arrivals]
        finish = [0.0] * n
        admitted: List[int] = []
        waiting = list(range(n))
        done = 0
        stall_windows = stall_windows or []

        # list scheduling: repeatedly dispatch the frontier unit with the
        # earliest feasible start.
        #  priority   — ties broken by request age (stream priority:
        #               staggers communication phases),
        #  fifo/naive — equalize progress (models SM fair sharing: all
        #               streams reach their comm phases together).
        while done < n:
            while waiting and len(admitted) < self.max_inflight:
                admitted.append(waiting.pop(0))
            best, best_start, best_key = None, math.inf, None
            for r in admitted:
                kind, dev, dur = units[cursor[r] % len(units)]
                res_free = (self.link_free if kind == 0
                            else self.dev_free)[dev]
                start = max(ready_at[r], res_free)
                if self.scheduling == "priority":
                    key = (round(start, 12), r)
                else:
                    key = (cursor[r], round(start, 12), r)
                if best_key is None or key < best_key:
                    best, best_start, best_key = r, start, key
            r = best
            kind, dev, dur = units[cursor[r] % len(units)]
            start = best_start
            for (w0, w1) in stall_windows:          # policy-switch stalls
                if w0 <= start < w1:
                    start = w1
            end = start + dur
            if kind == 0:
                self.link_free[dev] = end
                self.link_busy[dev] += dur
            else:
                self.dev_free[dev] = end
                self.dev_busy[dev] += dur
            ready_at[r] = end
            cursor[r] += 1
            if cursor[r] >= total_units:
                finish[r] = end
                admitted.remove(r)
                done += 1

        makespan = max(finish) - min(arrivals) if n else 0.0
        lats = [finish[r] - arrivals[r] for r in range(n)]
        return SimResult(makespan=makespan, completed=n, latencies=lats,
                         device_busy=self.dev_busy,
                         link_busy=self.link_busy)


# --------------------------------------------------------------------- #
def simulate_offline(graph: KernelGraph, plan: Plan, devices,
                     num_requests: int = 64,
                     scheduling: str = "priority",
                     pipelined: bool = True,
                     max_inflight: int = 16,
                     iters_per_request: int = 1,
                     bw_override: Optional[float] = None) -> SimResult:
    """All requests available at t=0; throughput = N / makespan."""
    tasks = stage_tasks(graph, plan, devices, bw_override)
    des = _DES(tasks, len(devices), scheduling, pipelined, max_inflight)
    return des.run([0.0] * num_requests, iters_per_request)


def simulate_online(graph: KernelGraph, plans: Dict[str, Plan], devices,
                    rate: float, num_requests: int = 200,
                    monitor: Optional[OnlineMonitor] = None,
                    seed: int = 0,
                    iters_per_request: int = 4,
                    bw_override: Optional[float] = None) -> SimResult:
    """Poisson arrivals at ``rate`` req/s; optional monitor switches
    between the provided {"latency": plan, "throughput": plan}."""
    rng = random.Random(seed)
    arrivals = []
    t = 0.0
    for _ in range(num_requests):
        t += rng.expovariate(rate)
        arrivals.append(t)

    if monitor is None:
        plan = plans.get("latency") or next(iter(plans.values()))
        tasks = stage_tasks(graph, plan, devices, bw_override)
        des = _DES(tasks, len(devices), "priority", True, 16)
        return des.run(arrivals, iters_per_request)

    # Windowed re-simulation with policy switching: requests arriving in
    # each window run under the policy the monitor chose at its start.
    # Exec latency baseline = unqueued single-request pass.
    result_lats: List[float] = []
    switches = 0
    stalls: List[Tuple[float, float]] = []
    cur_sched = monitor.policy
    # exec-only latency per policy (no queueing)
    exec_lat = {}
    for name, plan in plans.items():
        tasks = stage_tasks(graph, plan, devices, bw_override)
        exec_lat[name] = sum(t0.compute + t0.ingress
                             for t0 in tasks) * iters_per_request

    # process sequentially, windowed
    W = monitor.cfg.window
    idx = 0
    clock = 0.0
    des = None
    pending: List[float] = []
    makespan = 0.0
    seen_switches = 0
    while idx < len(arrivals) or pending:
        w_end = clock + W
        batch = []
        while idx < len(arrivals) and arrivals[idx] < w_end:
            batch.append(arrivals[idx])
            idx += 1
        batch = pending + batch
        pending = []
        if batch:
            plan = plans[monitor.policy if monitor.policy in plans
                         else "latency"]
            tasks = stage_tasks(graph, plan, devices, bw_override)
            pl = monitor.policy == "throughput"
            des = _DES(tasks, len(devices), "priority",
                       pipelined=pl, max_inflight=16 if pl else 2)
            sub = des.run(batch, iters_per_request, stall_windows=stalls)
            for a, l in zip(batch, sub.latencies):
                result_lats.append(l)
                monitor.record_request(a + l, l,
                                       exec_lat[monitor.policy
                                                if monitor.policy in exec_lat
                                                else "latency"])
                makespan = max(makespan, a + l)
        monitor.tick(w_end)
        if monitor.switches > seen_switches:
            # each switch stalls workers at the next iteration boundary
            stalls.append((w_end, w_end + monitor.cfg.switch_stall *
                           (monitor.switches - seen_switches)))
            seen_switches = monitor.switches
        clock = w_end

    return SimResult(makespan=makespan, completed=len(result_lats),
                     latencies=result_lats,
                     device_busy=[0.0] * len(devices),
                     link_busy=[0.0] * len(devices),
                     switches=monitor.switches)
