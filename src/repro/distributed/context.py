"""Ambient mesh context for layers that need explicit shard_map.

``with mesh_context(mesh): ...`` makes the mesh visible to model code
(the EP MoE path) without threading it through every call signature.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

from jax.sharding import Mesh

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev
