"""Logical-axis sharding rules with divisibility fallback.

Every parameter / cache / activation dimension is assigned a *logical*
axis by pattern-matching its tree path and rank; rule tables map logical
axes onto mesh axes.  A mapping is dropped (replicated) whenever the
dimension size is not divisible by the mesh-axis product — e.g.
gemma-2b's 8 query heads cannot shard over a 16-way ``model`` axis, so
heads replicate while its 16384-wide d_ff and 256000 vocab shard.

Rule tables:
  TRAIN_RULES  — FSDP over ``data`` (embed dim) x TP over ``model``
                 (heads / mlp / vocab / experts); batch over (pod, data).
  SERVE_RULES  — pure TP for weights; batch over (pod, data); decode KV
                 sequence over ``model`` (flash-decode style partial
                 attention, reduced by GSPMD).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LogicalAxes = Tuple[Optional[str], ...]

# --------------------------------------------------------------------- #
# Logical axis assignment by leaf path
# --------------------------------------------------------------------- #
_PARAM_PATTERNS = [
    # (path substring, rank -> logical axes); first match wins.
    # The embedding table's d_model dim is deliberately NOT FSDP-sharded
    # ("emb_d" -> None): sharding the unembed contraction dim over the
    # same axis as the batch forces GSPMD to all-gather full-batch logits
    # (67 GB/chip on seamless) — replicating the small table is free.
    ("embed/tok",      {2: ("vocab", "emb_d")}),
    ("embed/unembed",  {2: ("emb_d", "vocab")}),
    ("wq",             {3: ("embed", "heads", None),
                        4: ("layers", "embed", "heads", None)}),
    ("wk",             {3: ("embed", "kv_heads", None),
                        4: ("layers", "embed", "kv_heads", None)}),
    ("wv",             {3: ("embed", "kv_heads", None),
                        4: ("layers", "embed", "kv_heads", None)}),
    ("wo",             {3: ("heads", None, "embed"),
                        4: ("layers", "heads", None, "embed")}),
    ("bq",             {2: ("heads", None), 3: ("layers", "heads", None)}),
    ("bk",             {2: ("kv_heads", None),
                        3: ("layers", "kv_heads", None)}),
    ("bv",             {2: ("kv_heads", None),
                        3: ("layers", "kv_heads", None)}),
    ("router",         {2: ("embed", "expert"),
                        3: ("layers", "embed", "expert")}),
    ("w_gate",         {2: ("embed", "mlp"),
                        3: ("layers", "embed", "mlp"),
                        4: ("layers", "expert", "embed", "mlp")}),
    ("w_up",           {2: ("embed", "mlp"),
                        3: ("layers", "embed", "mlp"),
                        4: ("layers", "expert", "embed", "mlp")}),
    ("w_down",         {2: ("mlp", "embed"),
                        3: ("layers", "mlp", "embed"),
                        4: ("layers", "expert", "mlp", "embed")}),
    # mamba2
    ("in_proj",        {2: ("embed", "mamba_proj"),
                        3: ("layers", "embed", "mamba_proj")}),
    ("out_proj",       {2: ("mamba_inner", "embed"),
                        3: ("layers", "mamba_inner", "embed")}),
    ("conv_w",         {2: (None, "mamba_proj"),
                        3: ("layers", None, "mamba_proj")}),
    # rwkv6
    ("w_lora_a",       {2: ("embed", None), 3: ("layers", "embed", None)}),
    ("w_lora_b",       {2: (None, "embed"), 3: ("layers", None, "embed")}),
    ("w_r",            {2: ("embed", "rwkv_inner"),
                        3: ("layers", "embed", "rwkv_inner")}),
    ("w_k",            {2: ("embed", "rwkv_inner"),
                        3: ("layers", "embed", "rwkv_inner")}),
    ("w_v",            {2: ("embed", "rwkv_inner"),
                        3: ("layers", "embed", "rwkv_inner")}),
    ("w_g",            {2: ("embed", "rwkv_inner"),
                        3: ("layers", "embed", "rwkv_inner")}),
    ("w_o",            {2: ("rwkv_inner", "embed"),
                        3: ("layers", "rwkv_inner", "embed")}),
    ("ck",             {2: ("embed", "mlp"),
                        3: ("layers", "embed", "mlp")}),
    ("cv",             {2: ("mlp", "embed"),
                        3: ("layers", "mlp", "embed")}),
    ("cr",             {2: ("embed", "rwkv_inner"),
                        3: ("layers", "embed", "rwkv_inner")}),
]


def _leaf_axes(path: str, ndim: int) -> LogicalAxes:
    for pat, by_rank in _PARAM_PATTERNS:
        if pat in path and ndim in by_rank:
            return by_rank[ndim]
    return (None,) * ndim       # norms, biases, scalars: replicate


def param_logical_axes(params: Any) -> Any:
    """Tree of logical-axes tuples matching the parameter tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        spath = "/".join(str(getattr(k, "key", k)) for k in path)
        out.append(_leaf_axes(spath, np.ndim(leaf) if not
                              hasattr(leaf, "ndim") else leaf.ndim))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------- #
# Rule tables: logical axis -> mesh axis (or tuple of mesh axes)
# --------------------------------------------------------------------- #
TRAIN_RULES: Dict[str, Any] = {
    "embed": "data",            # FSDP shard of the contraction dim
    "emb_d": None,              # embed table d_model: replicate (see above)
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "mamba_proj": "model",
    "mamba_inner": "model",
    "rwkv_inner": "model",
    "layers": None,
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
}

SERVE_RULES: Dict[str, Any] = {
    "embed": None,              # weights replicated across data (TP only)
    "emb_d": None,
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "mamba_proj": "model",
    "mamba_inner": "model",
    "rwkv_inner": "model",
    "layers": None,
    "batch": ("pod", "data"),
    "seq": ("pod", "data"),     # long-context prefill: sequence parallel
    # decode: flash-decode style KV split over whatever batch left free
    "kv_seq": ("data", "model"),
}


def _mesh_axes_for(mesh: Mesh, rule) -> Tuple[Tuple[str, ...], int]:
    if rule is None:
        return (), 1
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    axes = tuple(a for a in axes if a in mesh.shape)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return axes, size


def spec_for(shape: Sequence[int], logical: LogicalAxes, mesh: Mesh,
             rules: Dict[str, Any]) -> P:
    """PartitionSpec with divisibility fallback to replication.

    Mesh axes already claimed by an earlier dimension of the same tensor
    are dropped from later rules (e.g. decode KV: batch takes (pod, data),
    kv_seq then maps onto the remaining model axis).  Rules whose full
    remaining product does not divide the dimension fall back to the
    largest dividing prefix, else replication.
    """
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical):
        if name is None:
            parts.append(None)
            continue
        rule = rules.get(name)
        axes, _ = _mesh_axes_for(mesh, rule)
        axes = tuple(a for a in axes if a not in used)
        # largest prefix of axes whose product divides dim
        chosen: Tuple[str, ...] = ()
        size = 1
        for a in axes:
            nxt = size * int(mesh.shape[a])
            if dim % nxt == 0:
                chosen = chosen + (a,)
                size = nxt
        if not chosen or size <= 1:
            parts.append(None)
            continue
        used.update(chosen)
        parts.append(chosen[0] if len(chosen) == 1 else tuple(chosen))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(tree_avals: Any, logical_tree: Any, mesh: Mesh,
                   rules: Dict[str, Any]) -> Any:
    """NamedSharding tree for an aval tree + logical-axes tree."""
    def one(aval, logical):
        return NamedSharding(mesh, spec_for(aval.shape, logical, mesh,
                                            rules))
    return jax.tree_util.tree_map(one, tree_avals, logical_tree)


def param_shardings(params_avals: Any, mesh: Mesh,
                    rules: Dict[str, Any]) -> Any:
    return tree_shardings(params_avals, param_logical_axes(params_avals),
                          mesh, rules)


# --------------------------------------------------------------------- #
# Cache / batch logical axes
# --------------------------------------------------------------------- #
def cache_logical_axes(cache: Any) -> Any:
    """KV caches: (L, B, T, H, D) -> (layers, batch, kv_seq, kv_heads, _);
    SSM states: (L, B, ...) -> batch-sharded only."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        spath = "/".join(str(getattr(k, "key", k)) for k in path)
        nd = leaf.ndim
        if ("kv" in spath or "cross" in spath) and nd == 5:
            out.append(("layers", "batch", "kv_seq", "kv_heads", None))
        elif nd >= 2:
            out.append(("layers", "batch") + (None,) * (nd - 2))
        else:
            out.append((None,) * nd)
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_logical_axes(batch_tree: Any, seq_axis: bool = True) -> Any:
    def one(leaf):
        nd = leaf.ndim
        if nd == 0:
            return ()
        if nd == 1:
            return ("batch",)
        return ("batch", "seq" if seq_axis else None) + \
            (None,) * (nd - 2)
    return jax.tree_util.tree_map(one, batch_tree)
