"""Training loop: jitted step, grad accumulation, checkpoints, recovery.

Single-host by default (CPU tests / examples); the same step function is
what ``launch/dryrun.py`` lowers onto the production meshes.  Fault
tolerance: every ``ckpt_every`` steps an async atomic checkpoint is
written; ``run`` auto-resumes from the latest complete checkpoint, and
the failure-injection hook lets tests kill the loop mid-step and verify
bitwise-identical resume (see tests/test_train_fault.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train import optim
from repro.train.compress import CompressionConfig, compress_decompress, \
    init_residual


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    keep: int = 3
    log_every: int = 10
    accum: int = 1                       # gradient accumulation
    compression: CompressionConfig = CompressionConfig("none")
    remat: bool = False
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 ocfg: Optional[optim.AdamWConfig] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.ocfg = ocfg or optim.AdamWConfig(
            warmup_steps=max(tcfg.steps // 10, 1),
            total_steps=tcfg.steps)
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
                     if tcfg.ckpt_dir else None)
        self.metrics: List[Dict[str, float]] = []
        self._build()

    # ------------------------------------------------------------------ #
    def _build(self):
        cfg, ocfg, tcfg = self.cfg, self.ocfg, self.tcfg

        def micro_loss(params, tokens, targets):
            return M.loss_fn(params, cfg, tokens, targets,
                             remat=tcfg.remat)

        def train_step(params, opt_state, residual, batch):
            if tcfg.accum > 1:
                B = batch["tokens"].shape[0]
                mb = B // tcfg.accum
                def one(i, acc):
                    g_acc, l_acc = acc
                    sl = lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * mb, mb, axis=0)
                    l, g = jax.value_and_grad(micro_loss)(
                        params, sl(batch["tokens"]), sl(batch["targets"]))
                    g_acc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                    return g_acc, l_acc + l
                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, loss = jax.lax.fori_loop(
                    0, tcfg.accum, one, (g0, jnp.zeros(())))
                grads = jax.tree_util.tree_map(
                    lambda g: g / tcfg.accum, grads)
                loss = loss / tcfg.accum
            else:
                loss, grads = jax.value_and_grad(micro_loss)(
                    params, batch["tokens"], batch["targets"])
            # cross-pod gradient compression (EF) before the slow
            # all-reduce; on one host this is the identity wire format.
            grads, residual = compress_decompress(
                tcfg.compression, grads, residual)
            params, opt_state = optim.apply(ocfg, grads, opt_state,
                                            params)
            return params, opt_state, residual, loss

        # No donation here: with fp32 params the master copy and the
        # params tree alias the same buffers (astype is a no-op and XLA
        # CSEs identical outputs), and donating an aliased buffer twice
        # is a runtime error.  The production (dry-run) train step relies
        # on XLA's SPMD buffer reuse instead.
        self.train_step = jax.jit(train_step)

    # ------------------------------------------------------------------ #
    def init_state(self, key=None):
        params = M.init_params(self.cfg, key or jax.random.PRNGKey(
            self.tcfg.seed))
        opt_state = optim.init(self.ocfg, params)
        grads0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        residual = init_residual(grads0)
        return {"params": params, "opt": opt_state,
                "residual": residual}

    def run(self, batches, state=None, start_step: int = 0,
            fail_at: Optional[int] = None) -> Dict[str, Any]:
        """Train from ``start_step``.  ``fail_at`` raises a simulated
        hardware failure AFTER that step's checkpointing window — the
        fault-tolerance tests restart with ``resume()``."""
        if state is None:
            state = self.init_state()
        params, opt_state, residual = (state["params"], state["opt"],
                                       state["residual"])
        t0 = time.perf_counter()
        step = start_step
        for step in range(start_step, self.tcfg.steps):
            batch = batches.batch_at(step)
            params, opt_state, residual, loss = self.train_step(
                params, opt_state, residual, batch)
            if step % self.tcfg.log_every == 0 or \
                    step == self.tcfg.steps - 1:
                self.metrics.append({"step": step,
                                     "loss": float(loss),
                                     "t": time.perf_counter() - t0})
            if self.ckpt and (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save_async(
                    step + 1,
                    {"params": params, "opt": opt_state,
                     "residual": residual})
            if fail_at is not None and step + 1 == fail_at:
                if self.ckpt:
                    self.ckpt.wait()
                raise SimulatedFailure(step + 1)
        if self.ckpt:
            self.ckpt.wait()
        return {"params": params, "opt": opt_state, "residual": residual,
                "last_step": step}

    def resume(self, batches) -> Dict[str, Any]:
        """Auto-resume from the latest checkpoint and finish training."""
        assert self.ckpt is not None
        template = self.init_state()
        step, state = self.ckpt.restore(template)
        return self.run(batches, state=state, start_step=step)


class SimulatedFailure(RuntimeError):
    def __init__(self, step: int):
        super().__init__(f"simulated node failure at step {step}")
        self.step = step
