"""AdamW with mixed precision and optional gradient compression hooks.

Implemented from scratch (no optax in the container).  Moments are fp32;
parameters may be bf16 (master-weight style: an fp32 copy lives in the
optimizer state and is the source of truth).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    master_fp32: bool = True


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any
    master: Any      # fp32 master weights (or None-pytree when disabled)


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio +
                            (1 - cfg.min_lr_ratio) * cos)


def init(cfg: AdamWConfig, params: Any) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree_util.tree_map(
        lambda p: p.astype(jnp.float32), params) if cfg.master_fp32 \
        else None
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros),
                      master=master)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply(cfg: AdamWConfig, grads: Any, state: AdamWState,
          params: Any) -> Tuple[Any, AdamWState]:
    """One AdamW update. Returns (new_params, new_state)."""
    step = state.step + 1
    lr = schedule(cfg, step.astype(jnp.float32))
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    src = state.master if cfg.master_fp32 else params

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p32)
        return m, v, p32

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(src)
    new_m, new_v, new_p32 = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        m2, v2, p2 = upd(g, m, v, p)
        new_m.append(m2)
        new_v.append(v2)
        new_p32.append(p2)
    mu = jax.tree_util.tree_unflatten(treedef, new_m)
    nu = jax.tree_util.tree_unflatten(treedef, new_v)
    p32 = jax.tree_util.tree_unflatten(treedef, new_p32)

    tgt_dtypes = jax.tree_util.tree_map(lambda p: p.dtype, params)
    new_params = jax.tree_util.tree_map(
        lambda p, dt: p.astype(dt), p32, tgt_dtypes)
    new_state = AdamWState(step=step, mu=mu, nu=nu,
                           master=p32 if cfg.master_fp32 else None)
    return new_params, new_state
