"""Gradient compression for the slow (cross-pod / DCN) all-reduce.

Two composable schemes with error feedback (EF — the residual of each
step's compression is added back next step, which keeps SGD convergent):

  * int8 quantization: per-tensor absmax scale, ~4x traffic reduction
    vs fp32 (2x vs bf16).
  * top-k sparsification: keep the k largest-magnitude entries
    (k = ratio * size), send values + indices.

On a real multi-pod mesh these run inside shard_map around the ``pod``
axis all-reduce; on CPU they are pure functions with the same signature,
property-tested for the EF invariant (compressed + residual == input).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------- #
def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_sparsify(x: jnp.ndarray, ratio: float
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    k = max(1, int(flat.size * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_densify(values: jnp.ndarray, idx: jnp.ndarray,
                 shape) -> jnp.ndarray:
    out = jnp.zeros(int(jnp.prod(jnp.array(shape))), values.dtype)
    return out.at[idx].set(values).reshape(shape)


# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "int8"         # "int8" | "topk" | "none"
    topk_ratio: float = 0.05


def init_residual(grads: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_decompress(cfg: CompressionConfig, grads: Any,
                        residual: Any) -> Tuple[Any, Any]:
    """Apply EF compression leaf-wise.  Returns (decompressed grads that
    would survive the wire, new residual).  The wire format (int8 / value
    +index pairs) is what the DCN all-reduce would carry."""
    if cfg.scheme == "none":
        return grads, residual

    def one(g, r):
        x = g.astype(jnp.float32) + r
        if cfg.scheme == "int8":
            q, s = quantize_int8(x)
            y = dequantize_int8(q, s)
        elif cfg.scheme == "topk":
            vals, idx = topk_sparsify(x, cfg.topk_ratio)
            y = topk_densify(vals, idx, x.shape)
        else:
            raise ValueError(cfg.scheme)
        return y.astype(g.dtype), x - y

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return new_g, new_r


def wire_bytes(cfg: CompressionConfig, grads: Any) -> float:
    """Bytes the compressed gradients occupy on the interconnect."""
    leaves = jax.tree_util.tree_leaves(grads)
    if cfg.scheme == "int8":
        return sum(l.size * 1 + 4 for l in leaves)
    if cfg.scheme == "topk":
        return sum(int(l.size * cfg.topk_ratio) * 8 for l in leaves)
    return sum(l.size * l.dtype.itemsize for l in leaves)
